"""Continuous ECG monitoring with streaming Bayesian uncertainty.

The paper's motivating deployment ("Bayesian LSTMs in medicine"): a
Bayesian classifier watches a patient's ECG as an unbounded stream and
emits, for every arriving chunk, the predictive distribution over beat
classes *for the signal so far* plus its uncertainty decomposition — high
mutual information (epistemic) marks windows the model has not seen the
like of, exactly when a monitor should escalate to a human.

The stream is served through ``repro.serve.StreamingEngine``: per-session
carried ``(h, c)`` resumes the sequence-fused Pallas kernel at every chunk
boundary, and the MC-dropout masks stay tied across the *whole session*
(paper §II-B tying, extended across resume boundaries), so the chunking of
the signal is invisible to the Bayesian draw — chunked and unchunked
serving are bit-identical.

Durability (PR 3): ``--kill-resume`` snapshots every live session mid-run,
throws the engine away (the simulated crash), restores into a brand-new
engine and finishes the streams there — then proves the resumed run is
bit-identical to an uninterrupted one.  A real deployment would run
``engine.snapshot(dir)`` on a cadence and ``engine.restore(dir)`` at boot;
nothing stochastic lives outside the snapshot (masks recompute from
``(seed, rows)``), so a crashed patient monitor loses nothing.

Co-design under load (PR 7): ``--controller`` injects a deterministic
overload burst (simulated tick-cost model — the real outputs are
untouched) and lets the online ``CoDesignController`` defend a p95 SLO:
it calibrates the roofline against the observed ticks, re-runs the
paper's DSE over the live knobs, downshifts S at a tick boundary, and the
demo *proves* the post-swap streams are bit-identical to an uninterrupted
run at the new config from the same carried state.

Adaptive sampling (dynamic S): ``--early-exit`` serves one flatline
("easy") stream and one real-ECG ("hard") stream through an engine with
``early_exit_threshold=0.0`` — the strictest setting, retiring chains only
when the uncertainty summary is *exactly* converged.  The flatline stream
collapses to the ``min_samples`` floor (its MC chains are provably
identical, so surplus chains buy nothing), the ECG stream keeps every
chain, and the demo proves the surviving streams' outputs are
bit-identical to a static-S engine's.

    PYTHONPATH=src python examples/ecg_monitoring.py [--steps 120]
    PYTHONPATH=src python examples/ecg_monitoring.py --smoke   # CI: tiny + fast
    PYTHONPATH=src python examples/ecg_monitoring.py --smoke --kill-resume
    PYTHONPATH=src python examples/ecg_monitoring.py --smoke --cell gru
    PYTHONPATH=src python examples/ecg_monitoring.py --smoke --precision int8
    PYTHONPATH=src python examples/ecg_monitoring.py --smoke --controller
    PYTHONPATH=src python examples/ecg_monitoring.py --smoke --early-exit
    PYTHONPATH=src python examples/ecg_monitoring.py --smoke --distill
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import classifier as clf, mcd
from repro.data import ecg
from repro.serve import StreamingEngine
from repro.train import optimizer, trainer


def train_quick(cfg, tx, ty, steps: int, seed: int = 0):
    """A few AdamW steps on the synthetic ECG5000 train split."""
    params = clf.init(jax.random.key(seed), cfg)
    if steps == 0:
        return params

    def loss(p, batch, step):
        x, y = batch
        rows = jnp.arange(x.shape[0], dtype=jnp.uint32)
        logits = clf.apply(p, x, rows, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1)), {}

    tr = trainer.Trainer(loss, params, trainer.TrainConfig(
        adamw=optimizer.AdamWConfig(lr=3e-3), log_every=0))
    pipe = ecg.Pipeline(tx, ty, batch_size=64, seed=seed)
    tr.run((tuple(map(jnp.asarray, b))
            for e in range(200) for b in pipe.epoch(e)), steps)
    return tr.params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120, help="training steps")
    ap.add_argument("--samples", type=int, default=8, help="S MC chains")
    ap.add_argument("--sessions", type=int, default=3)
    ap.add_argument("--chunk-len", type=int, default=28)
    ap.add_argument("--backend", default="pallas_seq")
    ap.add_argument("--precision", default=None,
                    choices=("fp32", "bf16", "int8", "int4"),
                    help="serving precision: quantize weights per-channel "
                    "(int8/int4 packed, dequantized in-register) and run "
                    "bf16 activations; default: native dtypes")
    ap.add_argument("--cell", default="lstm", choices=("lstm", "gru"),
                    help="recurrent unit (§III-A: GRU drops into the same "
                    "per-gate MCD design; streamed with h-only carries)")
    ap.add_argument("--mi-alarm", type=float, default=0.15,
                    help="epistemic (MI) escalation threshold, nats")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: untrained tiny model, a few chunks")
    ap.add_argument("--kill-resume", action="store_true",
                    help="snapshot mid-run, rebuild the engine from disk, "
                    "assert bit-identical continuation")
    ap.add_argument("--controller", action="store_true",
                    help="overload-burst demo: the co-design controller "
                    "downshifts under a simulated x4 load burst, recovers "
                    "the SLO, and the streams stay bit-identical across "
                    "the swap")
    ap.add_argument("--early-exit", action="store_true",
                    help="adaptive-sampling demo: a flatline stream "
                    "retires its surplus MC chains mid-stream, a real "
                    "ECG stream keeps all of them, and the retained "
                    "outputs stay bit-identical to a static-S engine")
    ap.add_argument("--distill", action="store_true",
                    help="distilled fast-path demo: both streams serve on "
                    "a single-row student; the flatline stream stays there "
                    "while the anomalous beat's predicted MI crosses the "
                    "threshold and escalates to full MC via fresh-chain "
                    "regrowth, bit-identical to an always-MC session "
                    "attached at that carry")
    ap.add_argument("--snapshot-dir", default=None,
                    help="where --kill-resume persists sessions "
                    "(default: a temp dir)")
    args = ap.parse_args()
    if args.smoke:
        args.steps, args.samples, args.sessions, args.chunk_len = 0, 4, 2, 10

    # Paper's best ECG classifier config (H=8, NL=3, placement YNY).
    cfg = clf.ClassifierConfig(
        hidden=8, num_layers=3, num_classes=ecg.NUM_CLASSES, cell=args.cell,
        mcd=mcd.MCDConfig(p=0.125, placement="YNY",
                          n_samples=args.samples, seed=0))
    tx, ty, ex, ey = ecg.make_ecg5000(seed=0)
    params = train_quick(cfg, tx, ty, args.steps)

    # Each session streams one held-out beat; smoke keeps it to a prefix.
    n_beats = args.sessions
    rng = np.random.default_rng(1)
    picks = rng.choice(len(ex), size=n_beats, replace=False)
    total_t = 3 * args.chunk_len if args.smoke else ecg.T_STEPS

    eng = StreamingEngine(params, cfg, backend=args.backend,
                          precision=args.precision,
                          max_sessions=args.sessions)
    for k in range(args.sessions):
        eng.open_session(f"patient-{k}")
    print(f"monitoring {args.sessions} sessions, chunk={args.chunk_len}, "
          f"S={args.samples}, cell={args.cell}, backend={args.backend}, "
          f"precision={args.precision or 'native'}, "
          f"model trained {args.steps} steps")

    pos = 0
    while pos < total_t:
        chunks = {
            f"patient-{k}": jnp.asarray(ex[picks[k]][pos:pos + args.chunk_len],
                                        jnp.float32)
            for k in range(args.sessions)}
        results = eng.step(chunks)
        pos += args.chunk_len
        for sid, res in sorted(results.items()):
            su = res.summary
            mi = float(su.mutual_information)
            cls = int(np.argmax(np.asarray(su.probs)))
            flag = "  <-- ESCALATE (high epistemic)" if mi > args.mi_alarm \
                else ""
            print(f"  {sid} t={res.steps_total:3d}: class={cls} "
                  f"H={float(su.predictive_entropy):5.3f} MI={mi:6.4f}{flag}")

    print()
    for k in range(args.sessions):
        sess = eng.close_session(f"patient-{k}")
        print(f"patient-{k}: true class {int(ey[picks[k]])}, served "
              f"{sess.steps} steps in {sess.chunks} chunks "
              f"(masks tied across all of them)")

    # The invariant that makes this safe to deploy: chunking is invisible.
    eng2 = StreamingEngine(params, cfg, backend=args.backend,
                           precision=args.precision, max_sessions=1)
    eng2.open_session("whole")
    whole = eng2.step({"whole": jnp.asarray(ex[picks[0]][:total_t],
                                            jnp.float32)})["whole"]
    eng3 = StreamingEngine(params, cfg, backend=args.backend,
                           precision=args.precision, max_sessions=1)
    eng3.open_session("split")
    split = None
    for a in range(0, total_t, 7):
        split = eng3.step({"split": jnp.asarray(
            ex[picks[0]][a:min(a + 7, total_t)], jnp.float32)})["split"]
    same = np.array_equal(np.asarray(whole.summary.probs),
                          np.asarray(split.summary.probs))
    print(f"\nchunked-equals-unchunked (7-step chunks vs one pass): "
          f"bit-identical={same}")
    assert same, "streaming resumption must be bit-identical"

    if args.kill_resume:
        kill_and_resume(params, cfg, ex, picks, args, total_t)
    if args.controller:
        controller_demo(params, cfg, ex, picks, args)
    if args.early_exit:
        early_exit_demo(cfg, ex, picks, args)
    if args.distill:
        distill_demo(cfg, tx, ty, ex, picks, args)


def kill_and_resume(params, cfg, ex, picks, args, total_t):
    """Snapshot mid-run, 'crash', restore into a fresh engine, compare.

    The uninterrupted engine and the snapshot→restore engine must emit
    bit-identical per-chunk summaries for every post-resume chunk — the
    PR 3 acceptance invariant, demonstrated here on the CI smoke path.
    """
    half = (total_t // (2 * args.chunk_len)) * args.chunk_len

    def serve(eng, lo, hi):
        out = {}
        pos = lo
        while pos < hi:
            chunks = {f"patient-{k}": jnp.asarray(
                ex[picks[k]][pos:pos + args.chunk_len], jnp.float32)
                for k in range(args.sessions)}
            out = eng.step(chunks)
            pos += args.chunk_len
        return out

    gold = StreamingEngine(params, cfg, backend=args.backend,
                           precision=args.precision,
                           max_sessions=args.sessions)
    for k in range(args.sessions):
        gold.open_session(f"patient-{k}")
    final_gold = serve(gold, 0, total_t)

    victim = StreamingEngine(params, cfg, backend=args.backend,
                             precision=args.precision,
                             max_sessions=args.sessions)
    for k in range(args.sessions):
        victim.open_session(f"patient-{k}")
    serve(victim, 0, half)
    with tempfile.TemporaryDirectory() as tmp:
        snap_dir = args.snapshot_dir or tmp
        path = victim.snapshot(snap_dir)
        print(f"\nkill-and-resume: snapshot at t={half} -> {path}")
        del victim                                  # the crash
        revived = StreamingEngine(params, cfg, backend=args.backend,
                                  precision=args.precision,
                                  max_sessions=args.sessions)
        revived.restore(snap_dir)
        final_res = serve(revived, half, total_t)

    for sid, want in sorted(final_gold.items()):
        got = final_res[sid]
        same = (got.steps_total == want.steps_total and np.array_equal(
            np.asarray(got.summary.probs), np.asarray(want.summary.probs)))
        print(f"  {sid}: resumed summary bit-identical={same}")
        assert same, f"{sid}: kill-and-resume diverged from the " \
            "uninterrupted stream"
    print("kill-and-resume OK: restored process == never-crashed process")


def early_exit_demo(cfg, ex, picks, args):
    """Adaptive sampling: easy streams shed chains, hard streams keep S.

    Served with ``early_exit_threshold=0.0`` — the strictest setting, so
    a session only retires chains when halving them moves its uncertainty
    summary by *exactly* nothing.  A flatline signal through a
    freshly-initialized stack is that case provably: zero input × zero
    biases keeps every activation at zero, the dropout masks multiply
    zeros, so all S chains are identical and MI is exactly 0 whatever the
    prefix.  A real ECG beat excites the chains differently (the masks
    bind to nonzero activations), the prefix summary moves, and the
    session keeps every chain.  The demo asserts both behaviours plus the
    retained-output invariant: the hard stream's per-chunk summaries are
    bit-identical to a static-S engine serving it solo.
    """
    # Fresh init (zero biases) — the flatline argument above needs it.
    demo_params = clf.init(jax.random.key(0), cfg)
    floor, S = 2, args.samples
    eng = StreamingEngine(demo_params, cfg, backend=args.backend,
                          max_sessions=2,
                          early_exit_threshold=0.0, min_samples=floor)
    solo = StreamingEngine(demo_params, cfg, backend=args.backend,
                           max_sessions=1)
    # "ecg" first: mask rows follow admission order, and the solo engine
    # hands its only session rows [0..S) — same rows, same Bayesian draw.
    eng.open_session("ecg")
    eng.open_session("flatline")
    solo.open_session("ecg")
    print(f"\nearly-exit demo: S={S} floor={floor} threshold=0.0 "
          f"(flatline vs real beat)")
    n_chunks, retained_same = 4, True
    for t in range(n_chunks):
        lo = t * args.chunk_len
        beat = jnp.asarray(ex[picks[0]][lo:lo + args.chunk_len], jnp.float32)
        res = eng.step({"flatline": jnp.zeros((args.chunk_len, 1)),
                        "ecg": beat})
        want = solo.step({"ecg": beat})["ecg"]
        retained_same &= np.array_equal(
            np.asarray(res["ecg"].summary.probs),
            np.asarray(want.summary.probs))
        s_easy = int(eng.store.get("flatline").rows.shape[0])
        s_hard = int(eng.store.get("ecg").rows.shape[0])
        m = eng.last_metrics
        print(f"  tick {t}: flatline S={s_easy} ecg S={s_hard} "
              f"active={m.active_chains} retired={m.reclaimed_rows}")
    s_easy = int(eng.store.get("flatline").rows.shape[0])
    s_hard = int(eng.store.get("ecg").rows.shape[0])
    assert s_easy == floor, \
        f"flatline stream should retire to the floor, holds {s_easy}"
    assert s_hard == S, \
        f"ecg stream should keep all {S} chains, holds {s_hard}"
    reclaimed = sum(m.reclaimed_rows for m in eng.metrics)
    assert reclaimed == S - floor, \
        f"expected {S - floor} retired chains, metrics counted {reclaimed}"
    print(f"  ecg stream vs static-S solo engine: "
          f"bit-identical={retained_same}")
    assert retained_same, "early exit perturbed a retained stream's outputs"
    print("early-exit demo OK: confident stream at the floor, uncertain "
          "stream at full S, retained outputs bit-identical")


def distill_demo(cfg, tx, ty, ex, picks, args):
    """Distilled fast path: easy traffic on one row, MC fallback on demand.

    Both streams open in ``mode="student"`` — a single deterministic row
    (the kernels skip its masks in-register) decoded through heads
    distilled right here from a quick-trained S-chain teacher.  The
    teacher's chain-axis MI is low on a flatline (nothing for the
    dropout ensemble to disagree about) and several times higher on a
    real beat, and the cached-target distillation
    (``DistillConfig.cache_targets``: one teacher sweep, thousands of
    dense-head steps) teaches the uncertainty head that separation.
    Served against a threshold placed between the student's own
    predictions for the two regimes, the flatline stream stays on the
    student forever while the anomalous beat escalates on its first
    chunk: ``SessionStore.grow`` retires the student row and regrows S
    fresh MC chains from the student's carry.  The demo then proves the
    escalation contract — the regrown stream's summaries are
    bit-identical to an always-MC engine serving a session attached with
    those rows and that carry.
    """
    import dataclasses

    from repro.core import distill
    from repro.train import distill as distill_train

    n_chunks, n_steps = 2, 6000
    # The student needs a teacher whose uncertainty is worth predicting: a
    # freshly-initialized stack is near-uniform everywhere (MI ~ 1e-3 on
    # any input), so the demo trains its own quick teacher.
    demo_params = train_quick(cfg, tx, ty, steps=max(args.steps, 120))
    S = args.samples
    rng = np.random.default_rng(2)
    cand_ids = rng.choice(len(ex), size=16, replace=False)
    cand = jnp.asarray(np.stack([ex[i][:args.chunk_len] for i in cand_ids]),
                       jnp.float32)
    # Of the held-out candidates, keep the four the TEACHER is most
    # epistemically uncertain about on their first chunk (a trained
    # monitor's flatline MI stays low at these horizons; abnormal beats'
    # is several times higher) — the regime the head must learn to flag.
    teacher_mi = np.asarray(distill.classifier_teacher_targets(
        demo_params, cand, cfg, n_samples=S).mutual_information)
    top = np.argsort(-teacher_mi)[:4]
    beats = cand[top]
    # The distillation stream: the first-chunk flatline window SHARES a
    # batch with the beats (per-batch Adam steps equalize gradients per
    # batch, not per sample — separate batches would let the two flatline
    # windows outvote the beats 2:1), plus the longer flatline prefix the
    # student will also be asked about (the det trunk is
    # chunking-invariant, so the served tick-k feature equals the
    # from-scratch prefix feature).
    xs = [jnp.concatenate([jnp.zeros((1, args.chunk_len, 1), jnp.float32),
                           beats]),
          jnp.zeros((1, n_chunks * args.chunk_len, 1), jnp.float32)]
    dcfg = distill_train.DistillConfig(n_samples=S, lr=3e-2,
                                       cache_targets=True)
    student, hist = distill_train.distill_classifier(
        demo_params, cfg, xs, n_steps, key=jax.random.key(1), dcfg=dcfg)

    # The distilled head must separate the exact traffic being served:
    # every flatline prefix the student will score vs an anomalous
    # beat's first chunk.  The anomalous stream is the beat the STUDENT
    # itself flags hardest, the alarm goes in between — the threshold
    # crossing is then the head's own call end to end.
    def mi_hat(x):
        _, states = clf.apply(demo_params, x, distill.det_rows(x.shape[0]),
                              cfg, return_state=True)
        return np.asarray(distill.classifier_student_summary(
            student, states[-1][0]).mutual_information)

    mi_flat = max(float(mi_hat(
        jnp.zeros((1, k * args.chunk_len, 1), jnp.float32))[0])
        for k in range(1, n_chunks + 1))
    stu_mi = mi_hat(beats)
    worst = int(np.argmax(stu_mi))
    anomaly = ex[cand_ids[top[worst]]]
    mi_anom = float(stu_mi[worst])
    assert mi_flat < mi_anom, "uncertainty head failed to separate regimes"
    thr = 0.5 * (mi_flat + mi_anom)
    print(f"\ndistill demo: S={S} student MI flatline<={mi_flat:.4f} "
          f"anomalous beat={mi_anom:.4f} threshold={thr:.4f} "
          f"(distilled {n_steps} steps, final loss={hist[-1]['loss']:.4f})")

    eng = StreamingEngine(demo_params, cfg, backend=args.backend,
                          max_sessions=2, student=student,
                          student_escalate_threshold=thr)
    eng.open_session("flatline", mode="student")
    eng.open_session("anomaly", mode="student")
    plain, identical = None, True
    for t in range(n_chunks):
        lo = t * args.chunk_len
        res = eng.step({
            "flatline": jnp.zeros((args.chunk_len, 1)),
            "anomaly": jnp.asarray(anomaly[lo:lo + args.chunk_len],
                                   jnp.float32)})
        m = eng.last_metrics
        print(f"  tick {t}: student_rows={m.student_rows} "
              f"escalations={m.escalations} active={m.active_chains} "
              f"anomaly_MI={float(res['anomaly'].summary.mutual_information):.4f}")
        if t == 0:
            # The anomalous beat must escalate on its very first chunk.
            assert m.escalations == 1 and m.student_rows == 2
            sess = eng.store.get("anomaly")
            assert sess.mode == "mc" and int(sess.rows.shape[0]) == S
            plain = StreamingEngine(demo_params, cfg, backend=args.backend,
                                    max_sessions=1)
            plain.attach_session(dataclasses.replace(
                sess, state=[tuple(layer) for layer in sess.state]))
        else:
            assert m.escalations == 0 and m.student_rows == 1
            want = plain.step({"anomaly": jnp.asarray(
                anomaly[lo:lo + args.chunk_len], jnp.float32)})["anomaly"]
            identical &= np.array_equal(
                np.asarray(res["anomaly"].summary.probs),
                np.asarray(want.summary.probs))
    assert eng.store.get("flatline").mode == "student", \
        "flatline stream should have stayed on the student fast path"
    print(f"  escalated stream vs always-MC engine attached at the carry: "
          f"bit-identical={identical}")
    assert identical, "escalation diverged from the always-MC twin"
    print("distill demo OK: easy stream on one student row, anomalous "
          "stream escalated to full MC, regrown chains bit-identical")


def controller_demo(params, cfg, ex, picks, args):
    """Overload burst → downshift → SLO recovered, streams bit-safe.

    The PR 7 acceptance invariant, demonstrated on the CI smoke path: tick
    durations come from a deterministic simulated cost model (a ×4 load
    burst from tick 8), the controller calibrates + searches + swaps, and
    every assertion below is the contract — ≥1 applied ``DecisionRecord``
    with a changed config, p95 back under the SLO within the cooldown
    budget, and post-swap outputs bit-identical to an uninterrupted engine
    at the new config resuming from the same carried state.
    """
    import dataclasses

    from repro.serve import (CoDesignController, ServingConfig,
                             SimulatedLoadSink, SLOPolicy)
    from repro.serve.controller import carry_dtypes, convert_session
    from repro.serve.scheduler import percentile

    n_ticks, chunk = 24, 8
    slo = SLOPolicy(p95_tick_s=3e-3)
    sink = SimulatedLoadSink(per_chain_step_s=1e-5, overhead_s=2e-4,
                             load=lambda t: 4.0 if t >= 8 else 1.0)
    sig = [np.tile(ex[picks[k]], (2, 1)) for k in range(args.sessions)]
    eng = StreamingEngine(params, cfg, backend=args.backend,
                          max_sessions=args.sessions,
                          chunk_capacity="auto", ladder=(chunk,),
                          metrics_sink=sink)
    for k in range(args.sessions):
        eng.open_session(f"patient-{k}")
    ctrl = CoDesignController(eng, slo, window=8, min_ticks=4,
                              cooldown_ticks=8)
    print(f"\ncontroller demo: SLO p95<={slo.p95_tick_s * 1e3:.0f}ms "
          f"(simulated x4 burst at tick 8) | knobs "
          f"S={list(ctrl.knobs.samples)}")
    post, swap_tick = [], None
    for t in range(n_ticks):
        chunks = {f"patient-{k}": jnp.asarray(
            sig[k][t * chunk:(t + 1) * chunk], jnp.float32)
            for k in range(args.sessions)}
        res = ctrl.engine.step(chunks)
        if swap_tick is not None:
            post.append({sid: np.asarray(r.summary.probs)
                         for sid, r in res.items()})
        rec = ctrl.maybe_reconfigure()
        if rec is not None:
            print(f"  tick {rec.tick}: [{rec.reason}] "
                  f"applied={rec.applied} winner={rec.winner}")
            if rec.applied and swap_tick is None:
                swap_tick = rec.tick

    applied = [r for r in ctrl.decisions if r.applied]
    assert applied, "controller never reconfigured under the burst"
    new = ServingConfig(**applied[0].winner)
    assert applied[0].winner != applied[0].current
    recov = [m.duration_s for m in sink.window()
             if swap_tick < m.tick <= swap_tick + ctrl.cooldown_ticks]
    p95 = percentile(recov, 95)
    print(f"  post-swap p95 {p95 * 1e3:.2f}ms "
          f"vs SLO {slo.p95_tick_s * 1e3:.0f}ms")
    assert p95 <= slo.p95_tick_s, "SLO not recovered within the cooldown"

    # Bit-identity across the boundary: an engine born at the new config,
    # resuming from the same carried state, must stream the same outputs.
    cfg2 = dataclasses.replace(
        cfg, mcd=cfg.mcd.replace(n_samples=new.n_samples))
    ref = StreamingEngine(params, cfg2, backend=args.backend,
                          max_sessions=args.sessions,
                          chunk_capacity="auto", ladder=(chunk,),
                          precision=new.precision)
    dts = carry_dtypes(cfg.cell, new.precision, ref.backend)
    for sess in ctrl.last_swap["old_sessions"]:
        ref.attach_session(convert_session(
            sess, n_samples=new.n_samples, part_dtypes=dts))
    same = True
    for t, probs in zip(range(swap_tick + 1, n_ticks), post):
        chunks = {f"patient-{k}": jnp.asarray(
            sig[k][t * chunk:(t + 1) * chunk], jnp.float32)
            for k in range(args.sessions)}
        want = ref.step(chunks)
        same &= all(np.array_equal(probs[sid],
                                   np.asarray(want[sid].summary.probs))
                    for sid in probs)
    print(f"  streams across the swap bit-identical={same}")
    assert same, "reconfiguration changed a stream's outputs"
    print("controller demo OK: downshift under burst, SLO recovered, "
          "streams bit-safe")


if __name__ == "__main__":
    main()

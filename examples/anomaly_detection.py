"""End-to-end driver: train the paper's Bayesian recurrent autoencoder on
ECG5000-compatible data and detect anomalies with uncertainty (paper §V-A1 +
Fig. 1), including checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/anomaly_detection.py [--steps 300]
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autoencoder as ae
from repro.core import bayesian, mcd, uncertainty as unc
from repro.data import ecg
from repro.train import optimizer, trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="ecg_ae_")

    # --- data: train on NORMAL beats only (reconstruction-based detection)
    tx, ty, ex, ey = ecg.make_ecg5000(seed=0)
    normal = jnp.asarray(tx[ty == 0])

    # --- paper's best anomaly architecture: H=16, NL=2, B=YNYN
    cfg = ae.AutoencoderConfig(
        hidden=16, num_layers=2,
        mcd=mcd.MCDConfig(p=0.125, placement="YNYN", n_samples=30, seed=0))
    params = ae.init(jax.random.key(0), cfg)

    def loss(p, batch, step):
        rows = jnp.arange(batch.shape[0], dtype=jnp.uint32)
        mean, log_var = ae.apply(p, batch, rows, cfg)
        return jnp.mean(ae.gaussian_nll(mean, log_var, batch)), {}

    tcfg = trainer.TrainConfig(
        adamw=optimizer.AdamWConfig(lr=3e-3),     # clip 3.0 / wd 1e-4 (paper)
        ckpt_dir=ckpt_dir, ckpt_every=100, log_every=50)
    tr = trainer.Trainer(loss, params, tcfg)      # auto-resumes if restarted
    n = normal.shape[0]
    batches = (normal[(i * 64) % max(n - 64, 1):][:64] for i in range(10 ** 6))
    tr.run(batches, args.steps)
    print(f"trained to step {tr.step} (checkpoints in {ckpt_dir})")

    # --- Bayesian anomaly scoring on the test set
    x = jnp.asarray(ex[:1024])
    is_anom = np.asarray(ey[:1024]) != 0
    means, log_vars = bayesian.predict(
        lambda p, xb, rows: ae.apply(p, xb, rows, cfg), params, x, cfg.mcd)
    s = unc.regression_summary(means, log_vars)
    score = np.asarray(unc.rmse(s, x))
    total_unc = np.asarray(s.total.mean(axis=(1, 2)))

    # ROC-AUC by rank statistic
    order = np.argsort(score)
    ranks = np.empty(len(score)); ranks[order] = np.arange(1, len(score) + 1)
    pos = is_anom.sum(); neg = len(score) - pos
    auc = (ranks[is_anom].sum() - pos * (pos + 1) / 2) / (pos * neg)

    print(f"\nreconstruction RMSE:  normal={score[~is_anom].mean():.3f}  "
          f"anomalous={score[is_anom].mean():.3f}")
    morph = np.asarray(ey[:1024]) == 1          # Fig. 1-style morphology case
    print(f"total uncertainty:    normal={total_unc[~is_anom].mean():.4f}  "
          f"morphology-anomaly={total_unc[morph].mean():.4f}"
          f"   (Fig. 1 behaviour strengthens with --steps ≥ 300)")
    print(f"anomaly ROC-AUC: {auc:.3f}")


if __name__ == "__main__":
    main()

"""Serve a zoo LM with Bayesian uncertainty per generated token.

Shows the paper's technique as a first-class serving feature on a modern
architecture: S MCD chains folded into the batch, masks tied across decode
steps, per-token predictive entropy + mutual information.

    PYTHONPATH=src python examples/uncertainty_serving.py --arch olmoe-1b-7b
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALIASES, get_config
from repro.models import backbone
from repro.serve.engine import BayesianEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ALIASES), default="olmoe-1b-7b")
    ap.add_argument("--samples", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=12)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)     # CPU-sized miniature
    cfg = cfg.replace(mcd=cfg.mcd.replace(n_samples=args.samples, p=0.1))
    params = backbone.init_params(jax.random.key(0), cfg, jnp.float32)

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 8),
                                       dtype=np.int32))
    kw = {}
    if cfg.family == "audio":
        kw["frames"] = jnp.asarray(rng.normal(
            size=(2, cfg.encoder_seq, cfg.d_model)).astype(np.float32))
    if cfg.family == "vlm":
        kw["patches"] = jnp.asarray(rng.normal(
            size=(2, cfg.num_patches, cfg.d_model)).astype(np.float32))

    eng = BayesianEngine(params, cfg, max_len=64)
    res = eng.generate(prompts, args.new_tokens, **kw)

    print(f"{cfg.name}: S={args.samples} chains, masks tied per chain across "
          f"all decode steps (recomputed from the counter-RNG — zero state)")
    for b in range(2):
        print(f"\nrequest {b}:")
        for t in range(args.new_tokens):
            tok = int(res.tokens[b, t])
            ent = float(res.predictive_entropy[b, t])
            mi = float(res.mutual_information[b, t])
            flag = "  <-- high epistemic" if mi > 0.3 else ""
            print(f"  step {t:2d}: token={tok:6d}  H={ent:5.3f}  "
                  f"MI={mi:6.4f}{flag}")


if __name__ == "__main__":
    main()

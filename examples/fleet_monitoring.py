"""Hospital-fleet monitoring: heterogeneous Bayesian RNN tenants, one engine.

The paper serves one Bayesian LSTM; a deployment serves a *fleet*.  This
demo runs three tenants with different models, tasks and priorities
through a single ``repro.serve.FleetEngine``:

* ``ward``   — the paper's Bayesian LSTM beat classifier (weight 3: the
  bedside monitors outrank everything else);
* ``anom``   — a GRU autoencoder scoring reconstruction uncertainty as an
  anomaly signal, with a ``decode_window`` so each chunk only replays the
  last W steps (weight 1);
* ``edge``   — the classifier again but int8-quantized, standing in for a
  low-priority research cohort on cheap capacity (weight 1).

Every tenant submits more streams than its row quota (the overload), so
admission runs through the shared weighted-fair queue: ``admit_per_tick``
caps fleet-wide admissions per tick and the weights ration that budget.
Mid-run the whole fleet is snapshotted, thrown away and restored into a
fresh process image (``kill/resume``) — one atomic manifest covers every
group engine, the tenant table, the fairness ledger and the queue.

The demo then *proves* the two properties that make co-tenancy safe:

1. **Heterogeneity pin** — for a tracked stream of every tenant, the
   fleet-served outputs (co-batched with other tenants, interrupted by the
   kill/resume) are bit-identical to a solo single-tenant
   ``StreamingEngine`` serving the same signal.  Sharing the tick is
   invisible to the Bayesian draw.
2. **Weighted fairness** — while every tenant is backlogged, admission
   shares track the 3:1:1 weights.

Full mode serves ~a thousand synthetic patients (scale with
``--patients 3000``); ``--smoke`` is the tiny CI path.

    PYTHONPATH=src python examples/fleet_monitoring.py
    PYTHONPATH=src python examples/fleet_monitoring.py --smoke
    PYTHONPATH=src python examples/fleet_monitoring.py --patients 3000
"""

import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autoencoder as ae, classifier as clf, mcd
from repro.data import ecg
from repro.serve import FleetEngine, StreamingEngine, TenantSpec

WINDOW = 16          # anom's decode_window (replay only the last W steps)


def make_specs(backend: str, samples: int):
    cfg_ward = clf.ClassifierConfig(
        hidden=8, num_layers=2, num_classes=ecg.NUM_CLASSES,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=samples,
                          seed=0))
    cfg_anom = ae.AutoencoderConfig(
        hidden=8, num_layers=1, cell="gru", decode_window=WINDOW,
        mcd=mcd.MCDConfig(p=0.125, placement="Y", n_samples=max(2, samples // 2),
                          seed=1))
    p_clf = clf.init(jax.random.key(0), cfg_ward)
    p_anom = ae.init(jax.random.key(1), cfg_anom)
    return [
        TenantSpec(name="ward", cfg=cfg_ward, params=p_clf, weight=3.0,
                   max_sessions=4, backend=backend),
        TenantSpec(name="anom", cfg=cfg_anom, params=p_anom, weight=1.0,
                   max_sessions=3, backend=backend),
        TenantSpec(name="edge", cfg=cfg_ward, params=p_clf, weight=1.0,
                   max_sessions=2, backend=backend, precision="int8"),
    ]


def make_streams(counts: dict[str, int], seed: int = 7):
    """Per-tenant synthetic patients: one ECG5000-compatible beat each."""
    _, _, ex, _ = ecg.make_ecg5000(seed)
    rng = np.random.default_rng(seed)
    return {t: [ex[i] for i in rng.integers(0, len(ex), size=n)]
            for t, n in counts.items()}


def build_fleet(args, specs):
    return FleetEngine(specs, admit_per_tick=args.admit_per_tick)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--patients", type=int, default=1000,
                    help="total synthetic patients across the fleet")
    ap.add_argument("--samples", type=int, default=4, help="S MC chains")
    ap.add_argument("--chunk-len", type=int, default=35)
    ap.add_argument("--backend", default="pallas_seq")
    ap.add_argument("--admit-per-tick", type=int, default=4,
                    help="fleet-wide admission budget per tick (the "
                    "weighted-fair queue rations it 3:1:1)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: a handful of patients, short streams")
    args = ap.parse_args()
    if args.smoke:
        args.patients, args.chunk_len, args.admit_per_tick = 20, 70, 2

    specs = make_specs(args.backend, args.samples)
    counts = {"ward": args.patients // 2,
              "anom": args.patients * 3 // 10,
              "edge": args.patients - args.patients // 2
              - args.patients * 3 // 10}
    streams = make_streams(counts)
    fleet = build_fleet(args, specs)
    print(f"fleet: {len(fleet.groups)} launch group(s) for "
          f"{len(specs)} tenants | " + " ".join(
              f"{s.name}[w={s.weight:g} rows={s.max_sessions} "
              f"patients={counts[s.name]}]" for s in specs))

    for t in sorted(counts):
        for k in range(counts[t]):
            fleet.admit(t, f"s{k}", priority=counts[t] - k)
    backlog0 = {t: fleet.queue.depth_of(t) for t in counts}
    print(f"admitted everything into the shared queue: backlog {backlog0}")

    kill_tick = 3
    fair_rounds, fair_admitted = 0, None    # ledger while ALL backlogged
    done = {t: 0 for t in counts}
    total = sum(counts.values())
    snap_dir = tempfile.mkdtemp(prefix="fleet_snap_")

    while sum(done.values()) < total:
        if fleet.tick == kill_tick:
            path = fleet.snapshot(snap_dir)
            print(f"tick {fleet.tick}: KILL — snapshot -> {path}")
            del fleet                                   # the crash
            fleet = build_fleet(args, specs)            # fresh process image
            fleet.restore(snap_dir)
            live = {t: len(v) for t, v in fleet.active_sessions.items()}
            print(f"RESUME: tick {fleet.tick} restored, live={live}, "
                  f"queue={ {t: fleet.queue.depth_of(t) for t in counts} }")

        chunks: dict[str, dict[str, jnp.ndarray]] = {}
        for t, sids in fleet.active_sessions.items():
            store = fleet.group_of(t).engine.store
            for s in sids:
                sig = streams[t][int(s[1:])]
                pos = store.get(f"{t}/{s}").steps
                if pos < len(sig):
                    chunks.setdefault(t, {})[s] = jnp.asarray(
                        sig[pos:pos + args.chunk_len], jnp.float32)
        fleet.step(chunks)
        if all(fleet.queue.depth_of(t) > 0 for t in counts):
            # All three tenants still have waiting streams: the weighted
            # drain is the only thing rationing rows right now.  The last
            # such ledger is where shares should reflect the weights.
            fair_rounds += 1
            fair_admitted = dict(fleet.queue.state()["admitted"])
        for t, sids in list(fleet.active_sessions.items()):
            store = fleet.group_of(t).engine.store
            for s in list(sids):
                if store.get(f"{t}/{s}").steps >= len(streams[t][int(s[1:])]):
                    fleet.close(t, s)
                    done[t] += 1
        if fleet.tick % 10 == 0 or sum(done.values()) == total:
            print(f"tick {fleet.tick:4d} | " + " ".join(
                f"{t}: done {done[t]}/{counts[t]} q={fleet.queue.depth_of(t)}"
                for t in sorted(counts)))

    if fair_admitted:
        share = {t: fair_admitted[t] / sum(fair_admitted.values())
                 for t in fair_admitted}
        print(f"\nadmissions while every tenant was backlogged "
              f"({fair_rounds} tick(s)): {fair_admitted} "
              f"shares={ {t: round(v, 3) for t, v in share.items()} } "
              f"(weights 3:1:1 -> 0.6:0.2:0.2)")
        assert share["ward"] > share["anom"] and \
            share["ward"] > share["edge"], \
            "the weight-3 tenant must take the largest admission share"

    heterogeneity_pin(specs, streams, args)
    print("\nfleet demo OK: heterogeneous tenants co-served, kill/resume "
          "survived, weighted shares honored, solo bit-identity held")


def heterogeneity_pin(specs, streams, args):
    """Fleet-served stream s0 of every tenant == a solo engine, bit for bit.

    The fleet co-batched each tenant with the others *and* crossed a
    snapshot/restore; the solo engine does neither.  Masks are functions of
    (seed, rows) and chunk boundaries are the same fixed ``--chunk-len``
    grid, so the outputs must match exactly — this is the ISSUE 8
    heterogeneity acceptance pin, run here on real signals.
    """
    print("\nheterogeneity pin: tenant s0 vs solo single-tenant engine")
    fleet = FleetEngine(specs, admit_per_tick=None)     # eager co-serving
    for s in specs:
        fleet.admit(s.name, "s0")
    finals: dict[str, object] = {}
    live = True
    while live:
        chunks = {}
        for s in specs:
            sig = streams[s.name][0]
            store = fleet.group_of(s.name).engine.store
            if f"{s.name}/s0" not in store.active:
                continue
            pos = store.get(f"{s.name}/s0").steps
            if pos >= len(sig):
                continue
            chunks[s.name] = {"s0": jnp.asarray(
                sig[pos:pos + args.chunk_len], jnp.float32)}
        live = bool(chunks)
        if live:
            for t, res in fleet.step(chunks).items():
                finals[t] = res["s0"]

    for s in specs:
        solo = StreamingEngine(s.params, s.resolved_cfg(), backend=s.backend,
                               precision=s.precision, max_sessions=1)
        solo.open_session("s0")
        sig = streams[s.name][0]
        want = None
        for a in range(0, len(sig), args.chunk_len):
            want = solo.step({"s0": jnp.asarray(
                sig[a:a + args.chunk_len], jnp.float32)})["s0"]
        got = finals[s.name]
        if hasattr(got.summary, "probs"):
            same = np.array_equal(np.asarray(got.summary.probs),
                                  np.asarray(want.summary.probs))
        else:
            same = (np.array_equal(np.asarray(got.summary.mean),
                                   np.asarray(want.summary.mean))
                    and got.summary.mean.shape[0] <= WINDOW)
        print(f"  {s.name} (S={s.resolved_cfg().mcd.n_samples}, "
              f"precision={s.precision or 'native'}): "
              f"bit-identical={same}")
        assert same, f"{s.name}: fleet serving diverged from solo serving"


if __name__ == "__main__":
    main()

"""Quickstart: Bayesian LSTM inference with uncertainty in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bayesian, classifier as clf, mcd, uncertainty as unc
from repro.data import ecg

# 1. An ECG beat classifier with MC-Dropout on layers 1 and 3 (paper's best:
#    H=8, NL=3, B=YNY) and S=30 Monte-Carlo samples at inference.
cfg = clf.ClassifierConfig(
    hidden=8, num_layers=3,
    mcd=mcd.MCDConfig(p=0.125, placement="YNY", n_samples=30, seed=0))
params = clf.init(jax.random.key(0), cfg)

# 2. A batch of (synthetic) ECG beats.
_, _, test_x, test_y = ecg.make_ecg5000(seed=0)
x = jnp.asarray(test_x[:8])

# 3. S stochastic forward passes — folded into the batch axis so weights are
#    fetched once (the paper's sample-wise pipelining, TPU-style).
logits = bayesian.predict(
    lambda p, xb, rows: clf.apply(p, xb, rows, cfg), params, x, cfg.mcd)
print("stacked MC logits:", logits.shape)          # [S, B, classes]

# 4. The Bayesian predictive distribution + uncertainty decomposition.
s = unc.classification_summary(logits)
for i in range(4):
    print(f"beat {i}: p={np.round(np.asarray(s.probs[i]), 3)} "
          f"H_total={float(s.predictive_entropy[i]):.3f} nats "
          f"MI_epistemic={float(s.mutual_information[i]):.3f} nats")
print("\n(untrained weights — see examples/anomaly_detection.py for the "
      "trained end-to-end pipeline)")

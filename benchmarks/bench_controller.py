"""Co-design controller costs: decision overhead, SLO recovery, throughput.

Three numbers the online DSE→serving loop has to earn:

* **decision overhead** — host µs of one ``plan()`` evaluation (summarize
  window + calibrate roofline + candidate search).  It runs at tick
  boundaries on the serving host, so it must be negligible next to a tick;
* **SLO recovery** — ticks from the onset of a deterministic ×4 load burst
  (``SimulatedLoadSink``) until p95 tick latency is back under the SLO,
  controller ON vs OFF.  OFF is the operator's status quo: the breach
  simply persists;
* **steady throughput** — post-recovery tokens/s p50 at the downshifted
  config vs the breached config, i.e. what the latency win costs in
  delivered chain-timesteps.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.dse.fpga_model import RNNArch
from repro.serve import (CoDesignController, ServingConfig, SimulatedLoadSink,
                         SLOPolicy, StreamingEngine, TickMetrics)
from repro.serve.scheduler import percentile

SLO = SLOPolicy(p95_tick_s=3e-3)
BURST_TICK, N_TICKS, CHUNK = 8, 28, 8


def _tick(i, dur, *, s=8, cap=64, slots=4):
    rows = slots * s
    live = slots * cap * s
    return TickMetrics(tick=i, capacity=cap, n_chunks=slots,
                       live_rows=slots * s, batch_rows=rows, queue_depth=0,
                       live_steps=slots * cap, live_chain_steps=live,
                       padded_steps=rows * cap, pad_waste=0.0,
                       duration_s=dur, tokens_per_sec=live / dur)


def bench_decision_overhead():
    arch = RNNArch(hidden=8, num_layers=2, placement="YN", weight_bits=32,
                   timesteps=64)
    ctrl = CoDesignController(
        None, SLO, config=ServingConfig(n_samples=8, chunk_capacity=64),
        arch=arch, slots=4, window=16, min_ticks=4)
    win = [_tick(i, 10e-3) for i in range(16)]       # breached: full search
    ts = []
    for _ in range(20):
        t0 = time.perf_counter()
        rec = ctrl.plan(win)
        ts.append(time.perf_counter() - t0)
    assert rec is not None and rec.applied
    us = sorted(ts)[len(ts) // 2] * 1e6
    common.emit("controller.plan.breach", us,
                f"candidates={len(rec.candidates)}")


def _serve(with_controller: bool):
    """One burst scenario; returns (sink, controller|None)."""
    from repro.core import classifier as clf, mcd
    cfg = clf.ClassifierConfig(
        hidden=8, num_layers=2, num_classes=4,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=4, seed=0))
    params = clf.init(jax.random.key(0), cfg)
    sink = SimulatedLoadSink(per_chain_step_s=1e-5, overhead_s=2e-4,
                             load=lambda t: 4.0 if t >= BURST_TICK else 1.0)
    eng = StreamingEngine(params, cfg, max_sessions=2,
                          chunk_capacity="auto", ladder=(CHUNK,),
                          metrics_sink=sink)
    eng.open_session("a")
    eng.open_session("b")
    ctrl = (CoDesignController(eng, SLO, window=8, min_ticks=4,
                               cooldown_ticks=8)
            if with_controller else None)
    sig = jax.random.normal(jax.random.key(1), (2, N_TICKS * CHUNK, 1))
    for t in range(N_TICKS):
        chunks = {"a": sig[0, CHUNK * t:CHUNK * (t + 1)],
                  "b": sig[1, CHUNK * t:CHUNK * (t + 1)]}
        (ctrl.engine if ctrl else eng).step(chunks)
        if ctrl:
            ctrl.maybe_reconfigure()
    return sink, ctrl


def _recovery_ticks(sink, min_ticks=4):
    """Ticks from burst onset until sliding p95 is back under the SLO."""
    window = [m.duration_s for m in sink.window()]
    for t in range(BURST_TICK + min_ticks, N_TICKS):
        if percentile(window[t - min_ticks:t], 95) <= SLO.p95_tick_s:
            return t - BURST_TICK
    return None


def bench_slo_recovery():
    for label, on in (("on", True), ("off", False)):
        sink, ctrl = _serve(on)
        rec_ticks = _recovery_ticks(sink)
        tail = [m.tokens_per_sec for m in sink.window()
                if m.tick >= N_TICKS - 8]
        p95 = percentile([m.duration_s for m in sink.window()
                          if m.tick >= N_TICKS - 8], 95)
        applied = sum(1 for r in ctrl.decisions if r.applied) if ctrl else 0
        common.emit(
            f"controller.recovery.{label}", 0.0,
            f"recovery_ticks={rec_ticks};steady_p95_ms={p95 * 1e3:.2f};"
            f"steady_tokens_p50={percentile(tail, 50):.0f};"
            f"decisions_applied={applied};"
            f"slo_met={bool(rec_ticks is not None and p95 <= SLO.p95_tick_s)}")


def run():
    bench_decision_overhead()
    bench_slo_recovery()


if __name__ == "__main__":
    run()

"""Multi-tenant fleet serving costs: co-batching, routing, fairness, snapshot.

Four numbers the fleet engine has to earn over N solo engines:

* **co-batching win** — one fleet tick for two tenants sharing a launch
  group (same params/config/backend) vs the same sessions served as two
  separate solo-engine ticks.  Folded tenants ride ONE batched launch per
  layer, so the fleet tick should cost about one solo tick, not two;
* **tenancy overhead** — two tenants in *different* launch groups vs two
  solo engines: the fleet's routing/namespacing/per-tenant metric tagging
  on top of the same two launches.  This is the price of the abstraction
  and it must be small;
* **drain cost** — host µs of one weighted-fair drain over a deep
  backlog (the per-tick admission path under overload);
* **snapshot/restore** — one atomic fleet manifest (every group store +
  tenant table + fairness ledger + queue) written and adopted back.
"""

from __future__ import annotations

import tempfile
import time

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import classifier as clf, mcd
from repro.serve import FleetEngine, StreamingEngine, TenantSpec

CHUNK, SESSIONS_PER_TENANT = 32, 4


def _cfg(s=4, seed=3):
    return clf.ClassifierConfig(
        hidden=8, num_layers=2, num_classes=5,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=seed))


def _chunks(tenants, t_steps=CHUNK):
    x = jnp.ones((t_steps, 1), jnp.float32)
    return {t: {f"s{k}": x for k in range(SESSIONS_PER_TENANT)}
            for t in tenants}


def _tick_us(step, chunks, iters=7):
    ts = []
    for _ in range(2):
        jax.block_until_ready(step(chunks))
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(step(chunks))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def _block(results):
    return jax.block_until_ready(
        [r.summary.probs for tr in results.values() for r in tr.values()])


def bench_cobatch_vs_solo():
    cfg = _cfg()
    params = clf.init(jax.random.key(0), cfg)

    def fleet_for(shared: bool):
        cfg_b = cfg if shared else _cfg(seed=4)
        params_b = params if shared else clf.init(jax.random.key(1), cfg_b)
        fleet = FleetEngine([
            TenantSpec(name="a", cfg=cfg, params=params, weight=3.0,
                       max_sessions=SESSIONS_PER_TENANT),
            TenantSpec(name="b", cfg=cfg_b, params=params_b,
                       max_sessions=SESSIONS_PER_TENANT)])
        for t in ("a", "b"):
            for k in range(SESSIONS_PER_TENANT):
                fleet.admit(t, f"s{k}")
        return fleet

    def solo():
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              max_sessions=SESSIONS_PER_TENANT)
        for k in range(SESSIONS_PER_TENANT):
            eng.admit(f"s{k}")
        return eng

    chunks = _chunks(("a", "b"))
    shared = fleet_for(shared=True)
    assert len(shared.groups) == 1
    us_shared = _tick_us(lambda c: _block(shared.step(c)), chunks)

    split = fleet_for(shared=False)
    assert len(split.groups) == 2
    us_split = _tick_us(lambda c: _block(split.step(c)), chunks)

    eng_a, eng_b = solo(), solo()
    x = chunks["a"]

    def two_solos(c):
        return jax.block_until_ready(
            [r.summary.probs
             for eng in (eng_a, eng_b) for r in eng.step(c).values()])

    us_solo2 = _tick_us(two_solos, x)
    common.emit("fleet.tick.shared_group", us_shared,
                f"2 tenants x {SESSIONS_PER_TENANT} sessions, 1 launch "
                f"group, vs 2 solo engines {us_solo2:.0f}us "
                f"({us_solo2 / us_shared:.2f}x)")
    common.emit("fleet.tick.split_groups", us_split,
                f"2 launch groups, overhead vs 2 solo engines "
                f"{(us_split / us_solo2 - 1) * 100:+.1f}%")


def bench_fair_drain():
    cfg = _cfg()
    params = clf.init(jax.random.key(0), cfg)
    depth, budget = 512, 16
    fleet = FleetEngine(
        [TenantSpec(name=n, cfg=cfg, params=params, weight=w,
                    max_sessions=4096)
         for n, w in (("a", 4.0), ("b", 2.0), ("c", 1.0))],
        max_pending=4096, admit_per_tick=budget)
    for i in range(depth):
        for n in ("a", "b", "c"):
            fleet.admit(n, f"s{i}")
    ts = []
    for _ in range(10):
        t0 = time.perf_counter()
        fleet._drain()
        ts.append(time.perf_counter() - t0)
    us = sorted(ts)[len(ts) // 2] * 1e6
    common.emit("fleet.drain.weighted_fair", us,
                f"budget {budget} from 3x{depth} backlog "
                f"({us / budget:.1f}us/admission)")


def bench_snapshot_restore():
    cfg = _cfg()
    params = clf.init(jax.random.key(0), cfg)

    params_b = clf.init(jax.random.key(1), _cfg(seed=4))

    def fresh():
        return FleetEngine([
            TenantSpec(name="a", cfg=cfg, params=params, weight=3.0,
                       max_sessions=SESSIONS_PER_TENANT),
            TenantSpec(name="b", cfg=_cfg(seed=4), params=params_b,
                       max_sessions=SESSIONS_PER_TENANT)])

    fleet = fresh()
    for t in ("a", "b"):
        for k in range(SESSIONS_PER_TENANT):
            fleet.admit(t, f"s{k}")
    _block(fleet.step(_chunks(("a", "b"))))
    with tempfile.TemporaryDirectory() as tmp:
        ts_s, ts_r = [], []
        for i in range(5):
            t0 = time.perf_counter()
            fleet.snapshot(tmp, step=i)
            ts_s.append(time.perf_counter() - t0)
            reader = fresh()
            t0 = time.perf_counter()
            reader.restore(tmp, step=i)
            ts_r.append(time.perf_counter() - t0)
        n_sess = 2 * SESSIONS_PER_TENANT
        common.emit("fleet.snapshot", sorted(ts_s)[2] * 1e6,
                    f"2 groups, {n_sess} sessions, atomic manifest")
        common.emit("fleet.restore", sorted(ts_r)[2] * 1e6,
                    f"2 groups, {n_sess} sessions adopted")


def run():
    bench_cobatch_vs_solo()
    bench_fair_drain()
    bench_snapshot_restore()


if __name__ == "__main__":
    run()

"""Tables I & II + the serving-precision frontier.

The paper's ablation (16-bit fixed point is lossless; Tables I/II) maps to
the serving path's ``precision`` knob (``repro.kernels.quantize``): bf16 is
the TPU-native 16-bit, int8/int4 are the beyond-paper per-channel weight
quantizations the Pallas kernels dequantize in-register.  Two result
families:

* ``table1.*`` / ``table2.*`` — the paper's precision ablation, now run
  through the real serving path (``precision=`` end-to-end) instead of a
  benchmark-local fake-quant.
* ``quant.frontier.*`` — accuracy vs uncertainty vs tokens/s vs resident
  weight bytes per precision, with throughput measured on the actual
  streaming hot path (``StreamingEngine`` ticks, ``pallas_seq``) so the
  frontier prices exactly what serving runs.
"""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.kernels import quantize


def _weight_bytes(cfg) -> dict[str, int]:
    """Resident recurrent weight bytes per precision (encoder stack)."""
    gates = 4 if getattr(cfg, "cell", "lstm") == "lstm" else 3
    dims, d = [], cfg.input_dim
    for _ in range(cfg.num_layers):
        dims.append((d, cfg.hidden))
        d = cfg.hidden
    return {p: sum(quantize.weight_bytes(i, h, gates, p) for i, h in dims)
            for p in quantize.PRECISIONS}


def _frontier(cfg, params, ex, n_sessions: int = 8, chunk: int = 70,
              ticks: int = 4):
    """Throughput of the streaming hot path per precision (tokens/s)."""
    from repro.serve.stream import StreamingEngine

    rows = []
    for prec in quantize.PRECISIONS:
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              max_sessions=n_sessions, precision=prec)
        for k in range(n_sessions):
            eng.open_session(f"s{k}")
        sig = np.asarray(ex[:n_sessions], np.float32)
        tps = []
        for t in range(ticks):
            lo = (t * chunk) % max(sig.shape[1] - chunk, 1)
            eng.step({f"s{k}": sig[k, lo:lo + chunk]
                      for k in range(n_sessions)})
            tps.append(eng.last_metrics.tokens_per_sec)
        # median over ticks; the first tick pays the compile
        rows.append((prec, float(np.median(tps))))
    return rows


def run():
    # Table II — classifier, through the serving-path precision knob
    cfg, p32 = common.train_classifier("YNY", hidden=8, num_layers=3)
    m32 = common.eval_classifier(cfg, p32)
    mbf = common.eval_classifier(cfg, p32, precision="bf16")
    m8 = common.eval_classifier(cfg, p32, precision="int8")
    m4 = common.eval_classifier(cfg, p32, precision="int4")
    common.emit("table2.clf.fp32", 0.0,
                f"acc={m32['accuracy']:.3f};ap={m32['ap']:.3f};"
                f"ar={m32['ar']:.3f};entropy={m32['entropy']:.3f}")
    common.emit("table2.clf.bf16", 0.0,
                f"acc={mbf['accuracy']:.3f};ap={mbf['ap']:.3f};"
                f"ar={mbf['ar']:.3f};entropy={mbf['entropy']:.3f};"
                f"acc_delta={mbf['accuracy']-m32['accuracy']:+.4f}")
    common.emit("table2.clf.int8w", 0.0,
                f"acc={m8['accuracy']:.3f};"
                f"acc_delta={m8['accuracy']-m32['accuracy']:+.4f}")
    common.emit("table2.clf.int4w", 0.0,
                f"acc={m4['accuracy']:.3f};"
                f"acc_delta={m4['accuracy']-m32['accuracy']:+.4f}")

    # Frontier: accuracy vs uncertainty calibration vs tokens/s vs bytes.
    metrics = {"fp32": m32, "bf16": mbf, "int8": m8, "int4": m4}
    wbytes = _weight_bytes(cfg)
    _, _, ex, _ = common.data()
    for prec, tps in _frontier(cfg, p32, ex):
        m = metrics[prec]
        common.emit(f"quant.frontier.{prec}", 0.0,
                    f"acc={m['accuracy']:.3f};entropy={m['entropy']:.3f};"
                    f"tokens_per_sec={tps:.0f};weight_bytes={wbytes[prec]}")

    # Table I — autoencoder
    cfg_a, a32 = common.train_autoencoder("YY", hidden=16, num_layers=1)
    am32 = common.eval_autoencoder(cfg_a, a32)
    ambf = common.eval_autoencoder(cfg_a, a32, precision="bf16")
    am8 = common.eval_autoencoder(cfg_a, a32, precision="int8")
    common.emit("table1.ae.fp32", 0.0,
                f"acc={am32['accuracy']:.3f};ap={am32['ap']:.3f};"
                f"auc={am32['auc']:.3f}")
    common.emit("table1.ae.bf16", 0.0,
                f"acc={ambf['accuracy']:.3f};ap={ambf['ap']:.3f};"
                f"auc={ambf['auc']:.3f};auc_delta={ambf['auc']-am32['auc']:+.4f}")
    common.emit("table1.ae.int8w", 0.0,
                f"auc={am8['auc']:.3f};auc_delta={am8['auc']-am32['auc']:+.4f}")


if __name__ == "__main__":
    run()

"""Tables I & II: precision ablation — the paper shows 16-bit fixed point is
lossless; the TPU-native 16-bit is bf16 (DESIGN.md §2).  We additionally
check an int8 post-training weight quantization (beyond-paper).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common


def _quantize_int8(params):
    def q(x):
        if x.ndim < 2:
            return x
        scale = jnp.max(jnp.abs(x), axis=tuple(range(x.ndim - 1)),
                        keepdims=True) / 127.0
        return (jnp.round(x / jnp.maximum(scale, 1e-12)) * scale).astype(x.dtype)
    return jax.tree.map(q, params)


def run():
    # Table II — classifier
    cfg, p32 = common.train_classifier("YNY", hidden=8, num_layers=3)
    m32 = common.eval_classifier(cfg, p32)
    pbf = jax.tree.map(lambda x: x.astype(jnp.bfloat16).astype(jnp.float32), p32)
    mbf = common.eval_classifier(cfg, pbf)
    m8 = common.eval_classifier(cfg, _quantize_int8(p32))
    common.emit("table2.clf.fp32", 0.0,
                f"acc={m32['accuracy']:.3f};ap={m32['ap']:.3f};"
                f"ar={m32['ar']:.3f};entropy={m32['entropy']:.3f}")
    common.emit("table2.clf.bf16", 0.0,
                f"acc={mbf['accuracy']:.3f};ap={mbf['ap']:.3f};"
                f"ar={mbf['ar']:.3f};entropy={mbf['entropy']:.3f};"
                f"acc_delta={mbf['accuracy']-m32['accuracy']:+.4f}")
    common.emit("table2.clf.int8w", 0.0,
                f"acc={m8['accuracy']:.3f};acc_delta={m8['accuracy']-m32['accuracy']:+.4f}")

    # Table I — autoencoder
    cfg_a, a32 = common.train_autoencoder("YY", hidden=16, num_layers=1)
    am32 = common.eval_autoencoder(cfg_a, a32)
    abf = jax.tree.map(lambda x: x.astype(jnp.bfloat16).astype(jnp.float32), a32)
    ambf = common.eval_autoencoder(cfg_a, abf)
    am8 = common.eval_autoencoder(cfg_a, _quantize_int8(a32))
    common.emit("table1.ae.fp32", 0.0,
                f"acc={am32['accuracy']:.3f};ap={am32['ap']:.3f};auc={am32['auc']:.3f}")
    common.emit("table1.ae.bf16", 0.0,
                f"acc={ambf['accuracy']:.3f};ap={ambf['ap']:.3f};auc={ambf['auc']:.3f};"
                f"auc_delta={ambf['auc']-am32['auc']:+.4f}")
    common.emit("table1.ae.int8w", 0.0,
                f"auc={am8['auc']:.3f};auc_delta={am8['auc']-am32['auc']:+.4f}")


if __name__ == "__main__":
    run()

"""Roofline report: reads the dry-run JSONL records and emits the
EXPERIMENTS.md §Roofline table (and the CSV lines for benchmarks.run)."""

from __future__ import annotations

import json
import os

from benchmarks import common


def load(path: str):
    full = os.path.join(common.RESULTS_DIR, path)
    if not os.path.exists(full):
        return []
    recs = {}
    for line in open(full):
        r = json.loads(line)
        recs[(r["arch"], r["shape"], r["mesh"])] = r   # last write wins
    return list(recs.values())


def markdown_table(recs) -> str:
    rows = ["| arch | shape | mesh | t_compute (s) | t_memory (s) | "
            "t_collective (s) | bottleneck | MODEL_FLOPs/HLO | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skipped | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR | — | — | — | — | — |")
            continue
        frac = r.get("roofline_fraction",
                     r.get("t_compute", 0)
                     / max(r.get("t_compute", 0), r.get("t_memory", 1e-30),
                           r.get("t_collective", 0)))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('t_compute', 0):.3e} | {r.get('t_memory', 0):.3e} | "
            f"{r.get('t_collective', 0):.3e} | {r.get('bottleneck', '—')} | "
            f"{r.get('useful_flops_ratio', 0):.3f} | {frac:.3f} |")
    return "\n".join(rows)


def run():
    recs = load("baseline_pod.jsonl")
    ok = [r for r in recs if r["status"] == "ok"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"])):
        t = max(r["t_compute"], r["t_memory"], r["t_collective"])
        common.emit(f"roofline.{r['arch']}.{r['shape']}", t * 1e6,
                    f"bottleneck={r['bottleneck']};"
                    f"frac={r.get('roofline_fraction', 0):.3f};"
                    f"useful={r.get('useful_flops_ratio', 0):.3f}")
    mp = load("baseline_multipod.jsonl")
    n_ok = sum(r["status"] == "ok" for r in mp)
    n_skip = sum(r["status"] == "skipped" for r in mp)
    common.emit("dryrun.multipod", 0.0,
                f"ok={n_ok};skipped={n_skip};"
                f"errors={len(mp) - n_ok - n_skip};cells={len(mp)}")
    common.emit("dryrun.pod", 0.0,
                f"ok={len(ok)};skipped={sum(r['status']=='skipped' for r in recs)};"
                f"errors={len(recs)-len(ok)-sum(r['status']=='skipped' for r in recs)};"
                f"cells={len(recs)}")


if __name__ == "__main__":
    run()

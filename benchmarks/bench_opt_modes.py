"""Tables V & VI: the optimization framework under different user modes.

Runs the full §IV flow on the benchmarked lookup table: every Opt-* mode
returns a (model, reuse-factor, latency) configuration; Opt-Latency trades
the Bayesian machinery away (paper's observation), metric modes pick
partially-Bayesian nets.
"""

from __future__ import annotations

from benchmarks import common
from benchmarks.bench_dse_sweep import build_tables
from repro.dse import fpga_model as fm
from repro.dse import search


def _candidates(rows, kind):
    out = []
    for r in rows:
        out.append(search.Candidate(
            arch=fm.RNNArch(hidden=r["hidden"], num_layers=r["num_layers"],
                            placement=r["placement"], kind=kind,
                            output_dim=1 if kind == "autoencoder" else 4),
            metrics={k: v for k, v in r.items()
                     if k not in ("hidden", "num_layers", "placement")}))
    return out


def run():
    tables = build_tables()
    ae_cands = _candidates(tables["anomaly"], "autoencoder")
    clf_cands = _candidates(tables["classification"], "classifier")

    # Table V — anomaly detection modes
    for mode in ("Opt-Latency", "Opt-Accuracy", "Opt-Precision", "Opt-AUC"):
        got = search.optimize(ae_cands, mode, batch=200)
        if got is None:
            common.emit(f"table5.{mode}", 0.0, "infeasible")
            continue
        common.emit(
            f"table5.{mode}", 0.0,
            f"A=H{got.arch.hidden}.NL{got.arch.num_layers}.B{got.arch.placement};"
            f"S={got.n_samples};R=({got.hw.r_x},{got.hw.r_h},{got.hw.r_d});"
            f"fpga_lat_ms={got.latency_s*1e3:.2f};"
            f"auc={got.metrics.get('auc', 0):.3f};acc={got.metrics.get('accuracy', 0):.3f}")

    # Table VI — classification modes
    for mode in ("Opt-Latency", "Opt-Accuracy", "Opt-Precision", "Opt-Recall",
                 "Opt-Entropy"):
        got = search.optimize(clf_cands, mode, batch=200)
        if got is None:
            common.emit(f"table6.{mode}", 0.0, "infeasible")
            continue
        common.emit(
            f"table6.{mode}", 0.0,
            f"A=H{got.arch.hidden}.NL{got.arch.num_layers}.B{got.arch.placement};"
            f"S={got.n_samples};R=({got.hw.r_x},{got.hw.r_h},{got.hw.r_d});"
            f"fpga_lat_ms={got.latency_s*1e3:.2f};"
            f"acc={got.metrics.get('accuracy', 0):.3f};"
            f"ap={got.metrics.get('ap', 0):.3f};ar={got.metrics.get('ar', 0):.3f};"
            f"entropy={got.metrics.get('entropy', 0):.3f}")


if __name__ == "__main__":
    run()

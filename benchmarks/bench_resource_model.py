"""Table III analogue: resource-model accuracy.

FPGA side: the paper's DSP formula vs the paper's published utilizations
(exact formula; the AE differs because §III-C underspecifies per-layer dims —
documented).  TPU side: the analytic HBM-residency model of
``repro.dse.tpu_model`` vs the dry-run's ``memory_analysis()`` (the measured
ground truth), per architecture — this is the model the TPU DSE trusts.
"""

from __future__ import annotations

import json
import os

from benchmarks import common
from repro.configs import get_config
from repro.dse import fpga_model as fm
from repro.dse import tpu_model
from repro.launch import analysis
from repro.models.config import SHAPES


def run():
    # --- FPGA DSP model (paper's own Table III check) ---
    ae = fm.RNNArch(16, 2, "YNYN", kind="autoencoder", output_dim=1)
    clf = fm.RNNArch(8, 3, "YNY")
    dsp_ae = fm.dsp_usage(ae, fm.HwConfig(16, 5, 16))
    dsp_clf = fm.dsp_usage(clf, fm.HwConfig(12, 1, 1))
    common.emit("table3.fpga.dsp.clf", 0.0,
                f"model={dsp_clf:.0f};paper_est=915;paper_used=898;"
                f"err_vs_used={abs(dsp_clf-898)/898*100:.1f}%")
    common.emit("table3.fpga.dsp.ae", 0.0,
                f"model={dsp_ae:.0f};paper_est=754;paper_used=758;"
                f"note=paper-underspecifies-AE-layer-dims")
    lat_ae = fm.latency_s(ae, fm.HwConfig(16, 5, 16), 50, 30) * 1e3
    lat_clf = fm.latency_s(clf, fm.HwConfig(12, 1, 1), 50, 30) * 1e3
    common.emit("table3.fpga.latency", 0.0,
                f"ae={lat_ae:.2f}ms(paper_est=42.25,meas=41.31);"
                f"clf={lat_clf:.2f}ms(paper_est=25.77,meas=25.23)")

    # --- TPU memory model vs dry-run memory_analysis ---
    path = os.path.join(common.RESULTS_DIR, "baseline_pod.jsonl")
    if not os.path.exists(path):
        common.emit("table3.tpu.memory", 0.0, "dryrun-results-missing")
        return
    recs = [json.loads(l) for l in open(path)]
    errs = []
    for r in recs:
        if r["status"] != "ok" or not r.get("memory"):
            continue
        cfg = get_config(r["arch"])
        cell = SHAPES[r["shape"]]
        hw = tpu_model.TpuHwConfig(
            data=16, model=16,
            fsdp=cfg.name.startswith("jamba"))
        # Apples-to-apples: predict the *resident state* (params + opt
        # moments + caches) and compare to memory_analysis argument bytes —
        # exact on any backend.  temp bytes are reported alongside but are
        # CPU-lowering-specific (f32 promotion, no TPU fusion) and excluded
        # from the accuracy score (see EXPERIMENTS.md §Roofline caveats).
        pred = tpu_model.memory_model(
            cfg, cell, hw) - (0.0 if cell.kind != "train" else
                              _activation_term(cfg, cell, hw))
        meas = r["memory"]["argument_bytes"]
        err = abs(pred - meas) / max(meas, 1) * 100
        errs.append(err)
        common.emit(
            f"table3.tpu.mem.{r['arch']}.{r['shape']}", 0.0,
            f"pred_resident_GB={pred/1e9:.2f};meas_args_GB={meas/1e9:.2f};"
            f"err={err:.0f}%;cpu_temp_GB={r['memory']['temp_bytes']/1e9:.1f}")
    if errs:
        med = sorted(errs)[len(errs) // 2]
        common.emit("table3.tpu.memory.summary", 0.0,
                    f"median_err={med:.0f}%;n={len(errs)};"
                    f"scope=resident-state-vs-argument-bytes")


def _activation_term(cfg, cell, hw) -> float:
    tokens_local = cell.global_batch * cell.seq_len / hw.dp / hw.microbatches
    per_layer = tokens_local * cfg.d_model * 2
    return per_layer * (cfg.num_layers if hw.remat else 8 * cfg.num_layers)


if __name__ == "__main__":
    run()

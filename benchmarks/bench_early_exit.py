"""Early-exit adaptive sampling: what retiring surplus chains buys.

Two numbers the dynamic-S refactor (ISSUE 9) has to earn over the static
engine it replaced:

* **throughput** — steady-state tick cost and signal throughput
  (stream-steps/s across sessions) on *confident* traffic, early-exit vs
  static S.  In dynamic launch mode retired chains shrink the actual
  batch, so a store full of converged streams should tick several times
  faster than one paying for all S chains forever.  The acceptance bar is
  >=2x on the all-confident workload.
* **quality** — on a mixed easy/hard workload, what the adaptive engine
  gives up: retained (full-S) sessions must match the static engine's
  predictions *bit-exactly* (their chains never changed), and the
  retired sessions' summaries are compared for prediction agreement and
  uncertainty drift.

Flatline streams through a freshly-initialized stack are the "confident"
traffic: zero input x zero biases keeps every activation at zero, all S
chains identical, MI exactly 0 — so ``threshold=0.0`` (the strictest
setting) retires them and provably nothing else.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import classifier as clf, mcd
from repro.serve import StreamingEngine

S, FLOOR, SESSIONS = 8, 1, 8
#: Throughput geometry: the per-chain compute must dominate the per-tick
#: fixed cost (host assembly, dispatch) for the row shrink to show up in
#: wall time — tiny hidden sizes are dispatch-bound on CPU and would
#: understate the win that scales with the model.
BENCH_HIDDEN, BENCH_CHUNK = 128, 64
#: Quality geometry: bit-exactness doesn't need the big model.
QUAL_HIDDEN, QUAL_CHUNK = 8, 32


def _cfg(hidden):
    return clf.ClassifierConfig(
        hidden=hidden, num_layers=2, num_classes=5,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=S, seed=3))


def _engine(params, cfg, threshold=None):
    # Default chunk_capacity (dynamic launch shapes): retirement shrinks
    # the real batch, which is the mode the speedup claim is about.
    return StreamingEngine(params, cfg, backend="pallas_seq",
                           max_sessions=SESSIONS,
                           early_exit_threshold=threshold,
                           min_samples=FLOOR)


def _open_all(eng):
    for k in range(SESSIONS):
        eng.open_session(f"s{k}")


def _tick_us(eng, chunks, iters=7):
    ts = []
    for _ in range(2):                       # warm the compiled graph
        jax.block_until_ready(
            [r.summary.probs for r in eng.step(chunks).values()])
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(
            [r.summary.probs for r in eng.step(chunks).values()])
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def bench_confident_throughput():
    """All-confident traffic: steady-state early-exit vs static S."""
    cfg = _cfg(BENCH_HIDDEN)
    params = clf.init(jax.random.key(0), cfg)
    zeros = {f"s{k}": jnp.zeros((BENCH_CHUNK, 1), jnp.float32)
             for k in range(SESSIONS)}

    static = _engine(params, cfg)
    _open_all(static)
    us_static = _tick_us(static, zeros)

    adaptive = _engine(params, cfg, threshold=0.0)
    _open_all(adaptive)
    # Drive to the floor first (staged halving: one stage per tick), so
    # the timed ticks measure the steady state, not the transition.
    for _ in range(4):
        adaptive.step(zeros)
    assert adaptive.store.active_chains == SESSIONS * FLOOR
    us_adaptive = _tick_us(adaptive, zeros)

    tokens = SESSIONS * BENCH_CHUNK           # signal steps per tick
    tps_static = tokens / (us_static / 1e6)
    tps_adaptive = tokens / (us_adaptive / 1e6)
    speedup = us_static / us_adaptive
    common.emit("early_exit/static_tick", us_static,
                f"S={S} rows={SESSIONS * S} tokens/s={tps_static:.0f}")
    common.emit("early_exit/adaptive_tick", us_adaptive,
                f"S={FLOOR} rows={SESSIONS * FLOOR} "
                f"tokens/s={tps_adaptive:.0f}")
    common.emit("early_exit/confident_speedup", us_static - us_adaptive,
                f"x{speedup:.2f} (bar: >=2x)")
    return speedup


def bench_mixed_quality():
    """Half easy / half hard: retained sessions bit-exact, drift bounded."""
    cfg = _cfg(QUAL_HIDDEN)
    params = clf.init(jax.random.key(0), cfg)
    n_ticks = 6
    rng = np.random.default_rng(5)
    hard_sig = rng.normal(0, 2, (SESSIONS // 2, n_ticks * QUAL_CHUNK, 1))

    def chunks_at(t):
        out = {}
        for k in range(SESSIONS):
            if k < SESSIONS // 2:             # easy half
                out[f"s{k}"] = jnp.zeros((QUAL_CHUNK, 1), jnp.float32)
            else:
                sig = hard_sig[k - SESSIONS // 2]
                out[f"s{k}"] = jnp.asarray(
                    sig[t * QUAL_CHUNK:(t + 1) * QUAL_CHUNK], jnp.float32)
        return out

    static = _engine(params, cfg)
    adaptive = _engine(params, cfg, threshold=0.0)
    _open_all(static)
    _open_all(adaptive)
    hard_exact, agree, mi_drift, reclaimed = True, 0, 0.0, 0
    for t in range(n_ticks):
        want = static.step(chunks_at(t))
        got = adaptive.step(chunks_at(t))
        reclaimed += adaptive.last_metrics.reclaimed_rows
        for k in range(SESSIONS):
            w, g = want[f"s{k}"].summary, got[f"s{k}"].summary
            if k >= SESSIONS // 2:            # hard: chains untouched
                hard_exact &= np.array_equal(np.asarray(w.probs),
                                             np.asarray(g.probs))
            agree += int(np.argmax(np.asarray(w.probs))
                         == np.argmax(np.asarray(g.probs)))
            mi_drift = max(mi_drift, abs(
                float(w.mutual_information) - float(g.mutual_information)))
    assert hard_exact, "early exit perturbed a full-S session's outputs"
    n_easy = SESSIONS // 2
    assert reclaimed == n_easy * (S - FLOOR)
    for k in range(SESSIONS):
        s_k = int(adaptive.store.get(f"s{k}").rows.shape[0])
        assert s_k == (FLOOR if k < n_easy else S)
    common.emit("early_exit/mixed_quality", 0.0,
                f"hard_bit_exact={hard_exact} "
                f"pred_agree={agree}/{n_ticks * SESSIONS} "
                f"max_mi_drift={mi_drift:.2e} reclaimed={reclaimed}")


def run():
    speedup = bench_confident_throughput()
    bench_mixed_quality()
    if speedup < 2.0:
        raise AssertionError(
            f"confident-traffic speedup x{speedup:.2f} below the 2x bar")


if __name__ == "__main__":
    run()

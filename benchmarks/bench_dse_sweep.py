"""Figs. 8 & 9: algorithmic DSE over A = {H, NL, B} — Pareto-optimal
architectures are at least partially Bayesian (the paper's headline claim).

Builds the lookup table the optimization framework (§IV) searches over.
"""

from __future__ import annotations

from benchmarks import common


AE_GRID = [      # (hidden, num_layers, placement)
    (16, 1, "NN"), (16, 1, "YY"), (16, 1, "YN"),
    (8, 1, "NN"), (8, 1, "YY"),
    (16, 2, "NNNN"), (16, 2, "YNYN"),
]

CLF_GRID = [
    (8, 1, "N"), (8, 1, "Y"),
    (8, 2, "NN"), (8, 2, "YN"),
    (8, 3, "NYN"), (8, 3, "YNY"), (8, 3, "YNN"), (8, 3, "NNN"),
]


def build_tables():
    def build():
        ae_rows = []
        for h, nl, b in AE_GRID:
            cfg, params = common.train_autoencoder(b, hidden=h, num_layers=nl)
            m = common.eval_autoencoder(cfg, params)
            ae_rows.append({"hidden": h, "num_layers": nl, "placement": b, **m})
        clf_rows = []
        for h, nl, b in CLF_GRID:
            cfg, params = common.train_classifier(b, hidden=h, num_layers=nl)
            m = common.eval_classifier(cfg, params)
            clf_rows.append({"hidden": h, "num_layers": nl, "placement": b, **m})
        return {"anomaly": ae_rows, "classification": clf_rows}
    return common.cached_json("dse_lookup.json", build)


def run():
    tables = build_tables()
    # Fig. 8: anomaly detection ROC summary
    best_bayes, best_point = None, None
    for row in tables["anomaly"]:
        tgt = best_point if set(row["placement"]) == {"N"} else best_bayes
        if set(row["placement"]) == {"N"}:
            if best_point is None or row["auc"] > best_point["auc"]:
                best_point = row
        else:
            if best_bayes is None or row["auc"] > best_bayes["auc"]:
                best_bayes = row
        common.emit(
            f"fig8.anomaly.H{row['hidden']}.NL{row['num_layers']}.B{row['placement']}",
            0.0, f"auc={row['auc']:.3f};ap={row['ap']:.3f};acc={row['accuracy']:.3f}")
    common.emit("fig8.summary", 0.0,
                f"bayes_auc={best_bayes['auc']:.3f};point_auc={best_point['auc']:.3f};"
                f"pareto_bayesian={best_bayes['auc'] >= best_point['auc']}")
    # Fig. 9: classification
    bb, bp = None, None
    for row in tables["classification"]:
        if set(row["placement"]) == {"N"}:
            if bp is None or row["accuracy"] > bp["accuracy"]:
                bp = row
        else:
            if bb is None or row["accuracy"] > bb["accuracy"]:
                bb = row
        common.emit(
            f"fig9.clf.H{row['hidden']}.NL{row['num_layers']}.B{row['placement']}",
            0.0, f"acc={row['accuracy']:.3f};ap={row['ap']:.3f};"
                 f"ar={row['ar']:.3f};entropy={row['entropy']:.3f}")
    common.emit("fig9.summary", 0.0,
                f"bayes_acc={bb['accuracy']:.3f};point_acc={bp['accuracy']:.3f}")


if __name__ == "__main__":
    run()

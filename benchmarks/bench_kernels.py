"""Kernel microbenchmarks: fused MCD-LSTM / masked-matmul vs unfused jnp.

On CPU the Pallas kernels run in interpret mode (slow by construction), so
wall-clock here compares the *unfused jnp* path against the *fused-semantics
jnp reference* (mask generation folded into the consumer); the structural
win (no mask tensors in HBM) is reported as bytes saved, which is what the
TPU roofline credits.

The step-vs-sequence sweep runs both fusion levels on the same (B, T, H, S)
grid and reports tokens/sec.  In interpret mode the measured gap is the
per-timestep kernel re-entry cost that the sequence kernel amortizes — the
CPU-visible proxy for the weight re-fetch traffic it removes on TPU; the
jnp-reference rows give the compiled-scan baseline on the same shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import cells, mcd
from repro.kernels import mcd_gru, mcd_lstm, ops, ref


def sweep_step_vs_seq():
    """tokens/sec for per-step vs sequence fusion over (B, T, H, S)."""
    seed, layer, p = 0, 0, 0.125
    for B, T, H, S in ((8, 16, 16, 1), (8, 16, 32, 1), (4, 32, 16, 2)):
        I = H
        ks = jax.random.split(jax.random.key(0), 2)
        wx = jax.random.normal(ks[0], (I, 4, H)) * 0.1
        wh = jax.random.normal(ks[1], (H, 4, H)) * 0.1
        b = jnp.zeros((4, H))
        # S MC samples fold into the batch axis (independent mask rows).
        rows = jnp.arange(S * B, dtype=jnp.uint32)
        x_seq = jax.random.normal(jax.random.key(1), (S * B, T, I))
        keys = mcd_lstm.gate_keys(seed, layer)
        tokens = S * B * T

        def step_fused(x):
            return ops.fused_lstm_layer(wx, wh, b, x, rows, seed, layer, p)[0]

        def seq_fused(x):
            return ops.fused_lstm_seq(wx, wh, b, x, rows, seed, layer, p)[0]

        def ref_scan(x):
            return ref.mcd_lstm_seq(x, wx, wh, b, rows, keys, p)[0]

        t_step = common.time_call(step_fused, x_seq, iters=2)
        t_seq = common.time_call(seq_fused, x_seq, iters=2)
        t_ref = common.time_call(jax.jit(ref_scan), x_seq, iters=3)
        tag = f"B{B}.T{T}.H{H}.S{S}"
        common.emit(f"kernel.lstm.step_fused.{tag}", t_step,
                    f"tokens_per_s={tokens / (t_step * 1e-6):.0f};"
                    f"kernel_entries={T}")
        common.emit(f"kernel.lstm.seq_fused.{tag}", t_seq,
                    f"tokens_per_s={tokens / (t_seq * 1e-6):.0f};"
                    f"kernel_entries=1;"
                    f"speedup_vs_step={t_step / t_seq:.2f}x")
        common.emit(f"kernel.lstm.jnp_ref_scan.{tag}", t_ref,
                    f"tokens_per_s={tokens / (t_ref * 1e-6):.0f};"
                    f"weight_refetches_per_seq={T}")


def sweep_gru_step_vs_seq():
    """GRU tokens/sec, step vs sequence fusion — the 3-gate counterpart.

    Same shapes as the LSTM sweep so the rows compare directly: the GRU
    trades one gate MVM (and the cell-state tail) for the reset-gate
    product, the paper framework's cheaper algorithmic configuration.
    """
    seed, layer, p = 0, 0, 0.125
    for B, T, H, S in ((8, 16, 16, 1), (8, 16, 32, 1), (4, 32, 16, 2)):
        I = H
        ks = jax.random.split(jax.random.key(0), 2)
        wx = jax.random.normal(ks[0], (I, 3, H)) * 0.1
        wh = jax.random.normal(ks[1], (H, 3, H)) * 0.1
        b = jnp.zeros((3, H))
        rows = jnp.arange(S * B, dtype=jnp.uint32)
        x_seq = jax.random.normal(jax.random.key(1), (S * B, T, I))
        keys = mcd_gru.gate_keys(seed, layer)
        tokens = S * B * T

        def step_fused(x):
            return ops.fused_gru_layer(wx, wh, b, x, rows, seed, layer, p)[0]

        def seq_fused(x):
            return ops.fused_gru_seq(wx, wh, b, x, rows, seed, layer, p)[0]

        def ref_scan(x):
            return ref.mcd_gru_seq(x, wx, wh, b, rows, keys, p)[0]

        t_step = common.time_call(step_fused, x_seq, iters=2)
        t_seq = common.time_call(seq_fused, x_seq, iters=2)
        t_ref = common.time_call(jax.jit(ref_scan), x_seq, iters=3)
        tag = f"B{B}.T{T}.H{H}.S{S}"
        common.emit(f"kernel.gru.step_fused.{tag}", t_step,
                    f"tokens_per_s={tokens / (t_step * 1e-6):.0f};"
                    f"kernel_entries={T}")
        common.emit(f"kernel.gru.seq_fused.{tag}", t_seq,
                    f"tokens_per_s={tokens / (t_seq * 1e-6):.0f};"
                    f"kernel_entries=1;"
                    f"speedup_vs_step={t_step / t_seq:.2f}x")
        common.emit(f"kernel.gru.jnp_ref_scan.{tag}", t_ref,
                    f"tokens_per_s={tokens / (t_ref * 1e-6):.0f};"
                    f"weight_refetches_per_seq={T}")


def run():
    B, T, I, H = 64, 140, 32, 32
    ks = jax.random.split(jax.random.key(0), 3)
    x_seq = jax.random.normal(ks[0], (B, T, I))
    params = cells.init_lstm(ks[1], I, H)
    rows = jnp.arange(B, dtype=jnp.uint32)

    @jax.jit
    def unfused(params, x_seq):
        # masks materialized up front (the naive S×mask-buffer design)
        zx, zh = mcd.lstm_gate_masks(0, 0, rows, I, H, 0.125)
        def step(carry, x_t):
            h, c = carry
            h, c = cells.lstm_step(params, h, c, x_t, zx, zh, 0.125)
            return (h, c), h
        init = (jnp.zeros((B, H)), jnp.zeros((B, H)))
        _, ys = jax.lax.scan(step, init, jnp.swapaxes(x_seq, 0, 1))
        return ys

    t_unfused = common.time_call(unfused, params, x_seq)
    mask_bytes = B * 4 * (I + H) * 4
    common.emit("kernel.lstm.unfused_jnp", t_unfused,
                f"mask_buffer_bytes={mask_bytes}")
    common.emit("kernel.lstm.fused_design", t_unfused,
                f"mask_buffer_bytes=0;hbm_saved={mask_bytes}B/layer;"
                f"validated=interpret(tests/test_kernels.py)")
    sweep_step_vs_seq()
    sweep_gru_step_vs_seq()


if __name__ == "__main__":
    run()

"""Kernel microbenchmarks: fused MCD-LSTM / masked-matmul vs unfused jnp.

On CPU the Pallas kernels run in interpret mode (slow by construction), so
wall-clock here compares the *unfused jnp* path against the *fused-semantics
jnp reference* (mask generation folded into the consumer); the structural
win (no mask tensors in HBM) is reported as bytes saved, which is what the
TPU roofline credits.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import cells, mcd


def run():
    B, T, I, H = 64, 140, 32, 32
    ks = jax.random.split(jax.random.key(0), 3)
    x_seq = jax.random.normal(ks[0], (B, T, I))
    params = cells.init_lstm(ks[1], I, H)
    rows = jnp.arange(B, dtype=jnp.uint32)

    @jax.jit
    def unfused(params, x_seq):
        # masks materialized up front (the naive S×mask-buffer design)
        zx, zh = mcd.lstm_gate_masks(0, 0, rows, I, H, 0.125)
        def step(carry, x_t):
            h, c = carry
            h, c = cells.lstm_step(params, h, c, x_t, zx, zh, 0.125)
            return (h, c), h
        init = (jnp.zeros((B, H)), jnp.zeros((B, H)))
        _, ys = jax.lax.scan(step, init, jnp.swapaxes(x_seq, 0, 1))
        return ys

    t_unfused = common.time_call(unfused, params, x_seq)
    mask_bytes = B * 4 * (I + H) * 4
    common.emit("kernel.lstm.unfused_jnp", t_unfused,
                f"mask_buffer_bytes={mask_bytes}")
    common.emit("kernel.lstm.fused_design", t_unfused,
                f"mask_buffer_bytes=0;hbm_saved={mask_bytes}B/layer;"
                f"validated=interpret(tests/test_kernels.py)")


if __name__ == "__main__":
    run()

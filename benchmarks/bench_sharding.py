"""Device-count sweep: tokens/s of the sharded data plane vs devices.

The ROADMAP's tokens/s trajectory finally gets a *scaling axis*: the same
recurrent-stack launch (and the same streaming-engine tick) measured at
1/2/4/… -way data sharding over `repro.launch.rnn_shardings`.  Two rows
per device count:

* ``stack.*`` — one ``run_stack(mesh=…)`` launch (batch = sessions × S MC
  chains partitioned over the data axis; the Fan-et-al. replicate-the-MC-
  chains trick at mesh scale),
* ``stream.*`` — a full ``StreamingEngine.step`` tick on a mesh-placed
  engine (slot padding to whole sessions per shard included, i.e. what a
  serving host actually dispatches).

Off-TPU the devices are forced host-CPU cores, so absolute tokens/s is an
interpret-mode proxy and *speedups can be < 1* (every "device" shares the
same silicon and the kernel interpreter is python-slow); what transfers to
TPU is that the work per device shrinks as 1/N while the results stay
bit-identical (asserted here on every rung).  Run with

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      PYTHONPATH=src python -m benchmarks.run   # or python benchmarks/bench_sharding.py
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import classifier as clf, mcd, rnn
from repro.launch.mesh import make_data_mesh
from repro.serve import StreamingEngine


def device_counts():
    n = len(jax.devices())
    return [c for c in (1, 2, 4, 8) if c <= n]


def sweep_stack(cell: str = "lstm"):
    """One sharded run_stack launch per device count; bit-identity checked."""
    B, T, H, NL, S = 16, 32, 8, 3, 2
    cfg = mcd.MCDConfig(p=0.125, placement="YNY", n_samples=S, seed=0)
    params = rnn.init_stack(jax.random.key(0), 1, (H,) * NL, cell=cell)
    rows = jnp.arange(B, dtype=jnp.uint32)
    x = jax.random.normal(jax.random.key(1), (B, T, 1), jnp.float32)
    lengths = jnp.full((B,), T, jnp.int32)
    masks = rnn.stack_mask_plan(cfg, NL)

    ref, _ = rnn.run_stack(params, x, masks, cfg.p, backend="pallas_seq",
                           rows=rows, seed=cfg.seed, lengths=lengths,
                           return_all_states=True, cell=cell)
    base_us = None
    for nd in device_counts():
        mesh = make_data_mesh(nd)

        def call():
            out, states = rnn.run_stack(params, x, masks, cfg.p,
                                        backend="pallas_seq", rows=rows,
                                        seed=cfg.seed, lengths=lengths,
                                        return_all_states=True, cell=cell,
                                        mesh=mesh)
            return out

        out = call()
        assert bool(jnp.all(out == ref)), f"sharded != unsharded at {nd} dev"
        us = common.time_call(call, warmup=1, iters=3)
        base_us = base_us or us
        tokens = B * T            # chain-timesteps per launch
        common.emit(
            f"shard.stack.{cell}.D{nd}.B{B}.T{T}", us,
            f"tokens_per_s={tokens / (us * 1e-6):.0f};"
            f"speedup_vs_1dev={base_us / us:.2f}x;bit_identical=1")


def sweep_stream():
    """Full engine ticks on a mesh-placed engine per device count."""
    n_sessions, chunk_len, s = 8, 20, 2
    cfg = clf.ClassifierConfig(
        hidden=8, num_layers=2,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=0))
    params = clf.init(jax.random.key(0), cfg)
    sigs = {f"s{k}": jax.random.normal(jax.random.key(k), (chunk_len, 1))
            for k in range(n_sessions)}
    # Unsharded first-tick results: the bit-identity oracle for every rung.
    oracle = StreamingEngine(params, cfg, backend="pallas_seq",
                             max_sessions=n_sessions)
    for k in range(n_sessions):
        oracle.open_session(f"s{k}")
    want = {sid: jnp.asarray(r.summary.probs)
            for sid, r in oracle.step(sigs).items()}
    base_us = None
    for nd in device_counts():
        mesh = make_data_mesh(nd) if nd > 1 else None
        eng = StreamingEngine(params, cfg, backend="pallas_seq",
                              max_sessions=n_sessions, mesh=mesh)
        for k in range(n_sessions):
            eng.open_session(f"s{k}")

        def tick():
            res = eng.step(sigs)
            jax.block_until_ready([r.summary.probs for r in res.values()])
            return res

        first = tick()          # tick 0 on fresh carries == the oracle's
        for sid, probs in want.items():
            assert bool(jnp.all(jnp.asarray(first[sid].summary.probs)
                                == probs)), \
                f"engine tick sharded != unsharded at {nd} devices ({sid})"
        us = common.time_call(tick, warmup=1, iters=3)
        base_us = base_us or us
        samples = n_sessions * chunk_len
        common.emit(
            f"shard.stream.D{nd}.N{n_sessions}.L{chunk_len}.S{s}", us,
            f"samples_per_s={samples / (us * 1e-6):.0f};"
            f"chain_steps_per_s={samples * s / (us * 1e-6):.0f};"
            f"speedup_vs_1dev={base_us / us:.2f}x;bit_identical=1")


def run():
    if len(jax.devices()) == 1:
        common.emit("shard.note", 0.0,
                    "note=single-device host, only the D1 rungs below ran; "
                    "set XLA_FLAGS=--xla_force_host_platform_device_count=8 "
                    "for the multi-device rungs")
    for cell in rnn.CELLS:
        sweep_stack(cell)
    sweep_stream()


if __name__ == "__main__":
    run()

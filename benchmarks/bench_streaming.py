"""Streaming session-serving throughput: (sessions, chunk_len, S) sweep.

Measures ``repro.serve.StreamingEngine.step`` wall-clock per tick and
reports samples/sec — signal timesteps served per second across all
sessions (each timestep is decoded by S MC chains, so chain-timesteps/sec
= samples/sec × S).  On CPU the Pallas backend runs in interpret mode, so
absolute numbers are proxies; the shape of the sweep (batching many
sessions into one launch vs serving them one by one) is what transfers to
TPU.  The ``solo`` rows serve the same load one session per launch — the
gap to the batched row is the session-batching win.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import classifier as clf, mcd
from repro.serve import StreamingEngine


def _engine(n_sessions: int, s: int, backend: str):
    cfg = clf.ClassifierConfig(
        hidden=8, num_layers=2,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=s, seed=0))
    params = clf.init(jax.random.key(0), cfg)
    return StreamingEngine(params, cfg, backend=backend,
                           max_sessions=n_sessions)


def _stream_tick(eng, chunks):
    res = eng.step(chunks)
    jax.block_until_ready([r.summary.probs for r in res.values()])
    return res


def sweep():
    backend = "pallas_seq"
    for n_sessions, chunk_len, s in ((1, 20, 4), (4, 20, 4), (8, 20, 4),
                                     (4, 70, 4), (4, 20, 8)):
        eng = _engine(n_sessions, s, backend)
        sigs = {f"s{k}": jax.random.normal(jax.random.key(k), (chunk_len, 1))
                for k in range(n_sessions)}
        for k in range(n_sessions):
            eng.open_session(f"s{k}")
        us = common.time_call(lambda: _stream_tick(eng, sigs),
                              warmup=1, iters=3)
        samples_per_s = n_sessions * chunk_len / (us * 1e-6)
        common.emit(
            f"stream.batched.N{n_sessions}.L{chunk_len}.S{s}", us,
            f"samples_per_s={samples_per_s:.0f};"
            f"chain_steps_per_s={samples_per_s * s:.0f}")

        # same load, one session per launch (no session batching)
        solo = _engine(n_sessions, s, backend)
        for k in range(n_sessions):
            solo.open_session(f"s{k}")
        us_solo = common.time_call(
            lambda: [_stream_tick(solo, {k: v}) for k, v in sigs.items()],
            warmup=1, iters=3)
        common.emit(
            f"stream.solo.N{n_sessions}.L{chunk_len}.S{s}", us_solo,
            f"samples_per_s={n_sessions * chunk_len / (us_solo * 1e-6):.0f};"
            f"batching_speedup={us_solo / us:.2f}x")


def run():
    sweep()


if __name__ == "__main__":
    run()

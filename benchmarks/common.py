"""Shared benchmark utilities: timing, quick training, the DSE lookup table."""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import autoencoder as ae
from repro.core import bayesian, classifier as clf, mcd, uncertainty as unc
from repro.data import ecg
from repro.train import optimizer, trainer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def time_call(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall-time (µs) of a jitted call (blocks on result)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


#: Every emit() of the process, in order — ``run.py --json`` serializes
#: this as the machine-readable baseline (e.g. BENCH_serving.json).
RECORDS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str) -> None:
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}")


_DATA = None


def data():
    global _DATA
    if _DATA is None:
        _DATA = ecg.make_ecg5000(0)
    return _DATA


def train_classifier(placement: str, hidden: int = 8, num_layers: int = 2,
                     steps: int = 120, p: float = 0.125, seed: int = 0,
                     lr: float = 3e-3, dtype=jnp.float32):
    tx, ty, _, _ = data()
    cfg = clf.ClassifierConfig(
        hidden=hidden, num_layers=num_layers,
        mcd=mcd.MCDConfig(p=p, placement=placement, n_samples=30, seed=seed))
    params = clf.init(jax.random.key(seed), cfg, dtype)

    def loss(prm, batch, step):
        x, y = batch
        rows = jnp.arange(x.shape[0], dtype=jnp.uint32)
        logits = clf.apply(prm, x, rows, cfg)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32))
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], -1)), {}

    tr = trainer.Trainer(loss, params,
                         trainer.TrainConfig(adamw=optimizer.AdamWConfig(lr=lr),
                                             log_every=0))
    pipe = ecg.Pipeline(tx, ty, batch_size=64, seed=seed)
    batches = (tuple(map(jnp.asarray, b))
               for e in range(200) for b in pipe.epoch(e))
    tr.run(batches, steps)
    return cfg, tr.params


def train_autoencoder(placement: str, hidden: int = 16, num_layers: int = 1,
                      steps: int = 120, p: float = 0.125, seed: int = 0,
                      lr: float = 3e-3, dtype=jnp.float32):
    tx, ty, _, _ = data()
    normal = jnp.asarray(tx[ty == 0])
    cfg = ae.AutoencoderConfig(
        hidden=hidden, num_layers=num_layers,
        mcd=mcd.MCDConfig(p=p, placement=placement, n_samples=30, seed=seed))
    params = ae.init(jax.random.key(seed), cfg, dtype)

    def loss(prm, batch, step):
        x = batch
        rows = jnp.arange(x.shape[0], dtype=jnp.uint32)
        mean, log_var = ae.apply(prm, x, rows, cfg)
        return jnp.mean(ae.gaussian_nll(mean, log_var, x)), {}

    tr = trainer.Trainer(loss, params,
                         trainer.TrainConfig(adamw=optimizer.AdamWConfig(lr=lr),
                                             log_every=0))
    n = normal.shape[0]
    batches = (normal[(i * 64) % max(n - 64, 1):][:64] for i in range(10_000))
    tr.run(batches, steps)
    return cfg, tr.params


def eval_classifier(cfg, params, n_samples: int | None = None,
                    n_test: int = 1024, precision: str | None = None):
    _, _, ex, ey = data()
    x, y = jnp.asarray(ex[:n_test]), jnp.asarray(ey[:n_test])
    mcfg = cfg.mcd if n_samples is None else cfg.mcd.replace(n_samples=n_samples)
    logits = bayesian.predict(
        lambda p, x_, r: clf.apply(p, x_, r, cfg, precision=precision),
        params, x, mcfg)
    s = unc.classification_summary(logits)
    probs = np.asarray(s.probs)
    yn = np.asarray(y)
    pred = probs.argmax(-1)
    acc = float((pred == yn).mean())
    # macro average precision / recall
    ap, ar = [], []
    for c in range(probs.shape[-1]):
        tp = float(((pred == c) & (yn == c)).sum())
        fp = float(((pred == c) & (yn != c)).sum())
        fn = float(((pred != c) & (yn == c)).sum())
        ap.append(tp / (tp + fp) if tp + fp else 0.0)
        ar.append(tp / (tp + fn) if tp + fn else 0.0)
    noise = jax.random.normal(jax.random.key(5), x.shape)
    s_noise = unc.classification_summary(
        bayesian.predict(
            lambda p, x_, r: clf.apply(p, x_, r, cfg, precision=precision),
            params, noise, mcfg))
    return {"accuracy": acc, "ap": float(np.mean(ap)), "ar": float(np.mean(ar)),
            "entropy": float(np.asarray(s_noise.predictive_entropy).mean())}


def eval_autoencoder(cfg, params, n_samples: int | None = None,
                     n_test: int = 768, precision: str | None = None):
    _, _, ex, ey = data()
    x = jnp.asarray(ex[:n_test])
    yn = np.asarray(ey[:n_test]) != 0          # anomaly = positive
    mcfg = cfg.mcd if n_samples is None else cfg.mcd.replace(n_samples=n_samples)
    means, log_vars = bayesian.predict(
        lambda p, x_, r: ae.apply(p, x_, r, cfg, precision=precision),
        params, x, mcfg)
    means = means.astype(jnp.float32)
    log_vars = None if log_vars is None else log_vars.astype(jnp.float32)
    s = unc.regression_summary(means, log_vars)
    score = np.asarray(unc.rmse(s, x))         # higher = more anomalous
    auc = _auc(yn, score)
    # accuracy / AP at the ROC-optimal cutoff (paper §V-A1)
    order = np.argsort(-score)
    tp = np.cumsum(yn[order])
    fp = np.cumsum(~yn[order])
    tpr = tp / max(yn.sum(), 1)
    fpr = fp / max((~yn).sum(), 1)
    youden = np.argmax(tpr - fpr)
    thr = score[order][youden]
    pred = score >= thr
    acc = float((pred == yn).mean())
    prec = float((pred & yn).sum() / max(pred.sum(), 1))
    return {"auc": auc, "accuracy": acc, "ap": prec,
            "rmse": float(score.mean()),
            "nll": float(np.asarray(unc.regression_nll(s, x)).mean())}


def _auc(y: np.ndarray, score: np.ndarray) -> float:
    order = np.argsort(score)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(score) + 1)
    pos = y.sum()
    neg = len(y) - pos
    if pos == 0 or neg == 0:
        return 0.5
    return float((ranks[y].sum() - pos * (pos + 1) / 2) / (pos * neg))


def cached_json(name: str, builder):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    out = builder()
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    return out

"""Distilled fast path: what single-chain student serving buys.

Three numbers the distillation stack (ISSUE 10) has to earn:

* **throughput frontier** — steady-state tick cost and signal throughput
  on confident traffic for the three serving modes: static S-chain MC,
  early-exit at the floor, and the distilled student.  A student session
  is ONE deterministic row against the MC engine's ``SESSIONS * S``, so
  the whole store ticks on a fraction of the batch.  The acceptance bar
  is >=3x student vs the S-chain MC engine.
* **escalation identity** — when a student's predicted uncertainty
  crosses the threshold, ``SessionStore.grow`` regrows fresh MC chains
  from the student's carry.  Fresh rows mean no mask reuse, so the
  escalated session must stream on *byte-identically* to an always-MC
  engine serving a session attached with those rows and that carry.
* **quality / calibration** — a student actually distilled from a
  trained ECG teacher: prediction agreement with the S-chain teacher,
  accuracy delta, and how well the uncertainty head tracks the
  teacher's chain-axis MI (the escalation signal's calibration).

Flatline traffic through a freshly-initialized stack is the "confident"
workload (same convention as ``bench_early_exit``): every activation
stays at zero, so MC chains agree exactly and the early-exit engine
provably retires to the floor.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import classifier as clf, distill, mcd
from repro.serve import StreamingEngine
from repro.serve.sessions import Session
from repro.train import distill as distill_train

S, FLOOR, SESSIONS = 8, 1, 8
#: Same throughput geometry as bench_early_exit: per-chain compute must
#: dominate per-tick fixed cost for the row shrink to show in wall time.
BENCH_HIDDEN, BENCH_CHUNK = 128, 64
#: Quality geometry: identity pins and calibration don't need the big model.
QUAL_HIDDEN, QUAL_CHUNK = 8, 32


def _cfg(hidden):
    return clf.ClassifierConfig(
        hidden=hidden, num_layers=2, num_classes=5,
        mcd=mcd.MCDConfig(p=0.125, placement="YN", n_samples=S, seed=3))


def _engine(params, cfg, **kw):
    return StreamingEngine(params, cfg, backend="pallas_seq",
                           max_sessions=SESSIONS, **kw)


def _open_all(eng, mode="mc"):
    for k in range(SESSIONS):
        eng.open_session(f"s{k}", mode=mode)


def _tick_us(eng, chunks, iters=7):
    ts = []
    for _ in range(2):                       # warm the compiled graph
        jax.block_until_ready(
            [r.summary.probs for r in eng.step(chunks).values()])
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(
            [r.summary.probs for r in eng.step(chunks).values()])
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def bench_frontier():
    """Steady-state tokens/s: student vs S-chain MC vs early-exit floor."""
    cfg = _cfg(BENCH_HIDDEN)
    params = clf.init(jax.random.key(0), cfg)
    student = distill.init_student(jax.random.key(1), cfg, params)
    zeros = {f"s{k}": jnp.zeros((BENCH_CHUNK, 1), jnp.float32)
             for k in range(SESSIONS)}
    tokens = SESSIONS * BENCH_CHUNK          # signal steps per tick

    static = _engine(params, cfg)
    _open_all(static)
    us_mc = _tick_us(static, zeros)

    adaptive = _engine(params, cfg, early_exit_threshold=0.0,
                       min_samples=FLOOR)
    _open_all(adaptive)
    for _ in range(4):                       # staged halving to the floor
        adaptive.step(zeros)
    assert adaptive.store.active_chains == SESSIONS * FLOOR
    us_ee = _tick_us(adaptive, zeros)

    # No escalation threshold: the timed ticks must stay on the student
    # path (a fresh unc head predicts MI ~ softplus(0) > 0 even here).
    fast = _engine(params, cfg, student=student)
    _open_all(fast, mode="student")
    us_stu = _tick_us(fast, zeros)
    assert fast.store.active_chains == SESSIONS
    assert fast.last_metrics.student_rows == SESSIONS

    for name, us, rows in (("mc_tick", us_mc, SESSIONS * S),
                           ("early_exit_tick", us_ee, SESSIONS * FLOOR),
                           ("student_tick", us_stu, SESSIONS)):
        common.emit(f"distill/{name}", us,
                    f"rows={rows} tokens/s={tokens / (us / 1e6):.0f}")
    speedup = us_mc / us_stu
    common.emit("distill/student_speedup", us_mc - us_stu,
                f"x{speedup:.2f} vs S={S} MC (bar: >=3x), "
                f"x{us_ee / us_stu:.2f} vs early-exit floor")
    return speedup


def bench_escalation_identity():
    """Escalated session == always-MC session attached at the same carry."""
    cfg = _cfg(QUAL_HIDDEN)
    params = clf.init(jax.random.key(0), cfg)
    student = distill.init_student(jax.random.key(1), cfg, params)
    rng = np.random.default_rng(7)
    sig = rng.normal(0, 2, (5 * QUAL_CHUNK, 1)).astype(np.float32)

    def chunk(t):
        return {"p0": jnp.asarray(sig[t * QUAL_CHUNK:(t + 1) * QUAL_CHUNK])}

    # Fresh unc head: predicted MI > 0 on any input, so threshold 0.0
    # escalates on the very first served chunk.
    esc = _engine(params, cfg, student=student,
                  student_escalate_threshold=0.0)
    esc.open_session("p0", mode="student")
    esc.step(chunk(0))
    assert esc.last_metrics.escalations == 1
    sess = esc.store.get("p0")
    assert sess.mode == "mc" and int(sess.rows.shape[0]) == S

    # The always-MC twin: same row ids, same (tiled) carry, no student.
    plain = _engine(params, cfg)
    plain.attach_session(dataclasses.replace(
        sess, state=[tuple(layer) for layer in sess.state]))

    exact = True
    for t in range(1, 5):
        a = esc.step(chunk(t))["p0"].summary
        b = plain.step(chunk(t))["p0"].summary
        for wa, wb in zip(a, b):
            exact &= np.array_equal(np.asarray(wa), np.asarray(wb))
    assert exact, "escalated session diverged from the attached MC twin"
    common.emit("distill/escalation_identity", 0.0,
                f"byte_identical={exact} ticks=4 rows={S}")


def bench_distilled_quality():
    """Distill from a trained ECG teacher; agreement + MI calibration."""
    cfg, params = common.train_classifier("YN", hidden=QUAL_HIDDEN, steps=120)
    tx, ty, ex, ey = common.data()
    # cache_targets: 8 teacher sweeps total, then thousands of cheap
    # dense-head steps over the cached features/targets.
    dcfg = distill_train.DistillConfig(n_samples=S, lr=1e-2,
                                       cache_targets=True)
    xs = (jnp.asarray(tx[(i * 64) % max(tx.shape[0] - 64, 1):][:64])
          for i in range(8))
    student, hist = distill_train.distill_classifier(
        params, cfg, xs, 2000, key=jax.random.key(2), dcfg=dcfg)

    n_test = 512
    x, yn = jnp.asarray(ex[:n_test]), np.asarray(ey[:n_test])
    teacher = distill.classifier_teacher_targets(params, x, cfg, n_samples=S)
    _, states = clf.apply(params, x, distill.det_rows(n_test), cfg,
                          return_state=True)
    stu = distill.classifier_student_summary(student, states[-1][0])

    t_pred = np.asarray(teacher.probs).argmax(-1)
    s_pred = np.asarray(stu.probs).argmax(-1)
    acc_t = float((t_pred == yn).mean())
    acc_s = float((s_pred == yn).mean())
    agree = float((t_pred == s_pred).mean())
    mi_t = np.asarray(teacher.mutual_information, dtype=np.float64)
    mi_s = np.asarray(stu.mutual_information, dtype=np.float64)
    mi_mae = float(np.abs(mi_s - mi_t).mean())
    corr = (float(np.corrcoef(mi_s, mi_t)[0, 1])
            if mi_t.std() > 0 and mi_s.std() > 0 else 0.0)
    common.emit("distill/quality", 0.0,
                f"teacher_acc={acc_t:.3f} student_acc={acc_s:.3f} "
                f"agree={agree:.3f} mi_mae={mi_mae:.3f} mi_corr={corr:.2f} "
                f"final_loss={float(hist[-1]['loss']):.4f}")
    assert agree >= 0.9, f"student/teacher prediction agreement {agree:.3f}"


def run():
    speedup = bench_frontier()
    bench_escalation_identity()
    bench_distilled_quality()
    if speedup < 3.0:
        raise AssertionError(
            f"student speedup x{speedup:.2f} below the 3x bar")


if __name__ == "__main__":
    run()

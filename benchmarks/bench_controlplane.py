"""Control-plane costs: admission, durable snapshots, adaptive shapes.

Three questions the PR 3 control plane has to answer with numbers:

* **admission** — how many submit→drain operations per second the priority
  queue sustains against a churning store (host-side bookkeeping; it must
  be negligible next to a model tick);
* **snapshot/restore** — wall-clock of persisting / rebuilding a full
  store of live sessions through ``repro.ckpt`` (atomic + sha256), vs the
  number of live sessions — the budget for the snapshot cadence;
* **pad waste** — padded-but-dead chain-timesteps under a static
  ``chunk_capacity`` vs the adaptive ladder, over a long-tailed synthetic
  chunk-length trace.  The static number is what an operator guesses; the
  adaptive number is what the scheduler earns (while keeping compiles
  bounded by the ladder length).
"""

from __future__ import annotations

import shutil
import tempfile
import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.serve import (AdmissionQueue, AdaptiveTickScheduler, SessionStore,
                         restore_store, snapshot_store)


def _host_us(fn, iters=3):
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2] * 1e6


def bench_admission(n_requests=2000, capacity=64):
    def churn():
        store = SessionStore(n_samples=4, max_sessions=capacity)
        queue = AdmissionQueue(max_pending=n_requests)
        rng = np.random.default_rng(0)
        served = 0
        for k in range(n_requests):
            queue.submit(f"s{k}", priority=int(rng.integers(0, 3)))
        while len(queue) or len(store):
            queue.drain(store)
            for sid in store.active:        # every live stream finishes
                store.evict(sid)
                served += 1
        assert served == n_requests
    us = _host_us(churn)
    common.emit(f"controlplane.admission.N{n_requests}", us,
                f"requests_per_s={n_requests / (us * 1e-6):.0f}")


def _filled_store(n_sessions, s=4, hidden=8, layers=2):
    store = SessionStore(n_samples=s, max_sessions=n_sessions)
    for k in range(n_sessions):
        sess = store.admit(f"s{k}")
        sess.state = [(jnp.zeros((s, hidden)) + k,
                       jnp.zeros((s, hidden), jnp.float32) + k)
                      for _ in range(layers)]
        sess.steps, sess.chunks = 100 * k, k
    return store


def bench_snapshot(n_sessions):
    store = _filled_store(n_sessions)
    tmp = tempfile.mkdtemp(prefix="bench_ckpt_")
    try:
        us_save = _host_us(lambda: snapshot_store(tmp, store, step=0))
        us_load = _host_us(lambda: restore_store(tmp, step=0))
        common.emit(f"controlplane.snapshot.K{n_sessions}", us_save,
                    f"sessions={n_sessions}")
        common.emit(f"controlplane.restore.K{n_sessions}", us_load,
                    f"sessions={n_sessions}")
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


def _chunk_trace(n_ticks=400, n_sessions=8, seed=0):
    """Long-tailed chunk lengths: mostly short beats, rare long bursts."""
    rng = np.random.default_rng(seed)
    base = rng.integers(8, 32, size=(n_ticks, n_sessions))
    burst = rng.random((n_ticks, n_sessions)) < 0.05
    return np.where(burst, rng.integers(100, 240, size=base.shape), base)


def bench_pad_waste():
    trace = _chunk_trace()
    n_sessions = trace.shape[1]

    def waste(capacity_fn):
        live = padded = 0
        for lens in trace:
            cap = capacity_fn(lens)
            live += int(lens.sum())
            padded += cap * n_sessions
        return 1.0 - live / padded

    # A static capacity must cover the trace max (the engine rejects longer
    # chunks), so the honest static baseline is the top rung; smaller
    # static settings are shown as what they'd cost *if* the load allowed.
    for cap in (64, 128, 256):
        common.emit(f"controlplane.pad_waste.static{cap}", 0.0,
                    f"pad_waste={waste(lambda lens, c=cap: c):.3f}"
                    + ("" if cap >= trace.max() else ";rejects_bursts"))
    for pct in (100.0, 90.0):
        sched = AdaptiveTickScheduler(percentile=pct)
        w = waste(lambda lens: sched.plan(lens))
        shapes = AdaptiveTickScheduler(percentile=pct)
        used = len({shapes.plan(lens) for lens in trace})
        common.emit(f"controlplane.pad_waste.adaptive_p{pct:.0f}", 0.0,
                    f"pad_waste={w:.3f};distinct_shapes={used}")


def run():
    bench_admission()
    for k in (4, 16, 64):
        bench_snapshot(k)
    bench_pad_waste()


if __name__ == "__main__":
    run()

"""Table IV analogue: latency/efficiency comparison.

The paper compares FPGA vs CPU vs GPU wall-clock.  This container has one
CPU, so we measure what is measurable and model the rest, clearly labeled:

  * measured: CPU (XLA-compiled JAX) Bayesian inference latency at the
    paper's batch sizes (50/200) and S=30 — the paper's own CPU baseline row
    (their Xeon took seconds; so does any CPU).
  * measured: fold-S-into-batch vs loop-over-S on CPU — the amortization
    the paper's sample-wise pipelining achieves in hardware.
  * modeled: the paper's FPGA latency model (§IV-C, validated <3%) and the
    TPU roofline latency from repro.dse.tpu_model — the "accelerator" rows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import bayesian, classifier as clf, mcd, rnn
from repro.dse import fpga_model as fm


def stack_backend_latency():
    """run_stack backends on the paper's classifier stack: tokens/sec each.

    The reference rows are compiled XLA (the CPU/GPU-baseline analogue); the
    pallas rows run in interpret mode on CPU, where step-vs-seq isolates the
    per-timestep kernel re-entry the sequence fusion removes.
    """
    cfg = mcd.MCDConfig(p=0.125, placement="YNY", seed=0)
    hiddens = (8, 8, 8)
    params = rnn.init_stack(jax.random.key(0), 1, hiddens)
    for B, T in ((8, 35), (16, 70)):
        x = jax.random.normal(jax.random.key(1), (B, T, 1))
        rows = jnp.arange(B, dtype=jnp.uint32)
        masks = rnn.sample_stack_masks(cfg, rows, 1, hiddens)
        tokens = B * T

        runs = {
            "reference": jax.jit(lambda p_, x_: rnn.run_stack(
                p_, x_, masks, cfg.p)[1][0]),
            "pallas_step": lambda p_, x_: rnn.run_stack(
                p_, x_, masks, cfg.p, backend="pallas_step", rows=rows,
                seed=cfg.seed)[1][0],
            "pallas_seq": lambda p_, x_: rnn.run_stack(
                p_, x_, masks, cfg.p, backend="pallas_seq", rows=rows,
                seed=cfg.seed)[1][0],
        }
        times = {}
        for name, fn in runs.items():
            times[name] = common.time_call(fn, params, x, iters=2)
            common.emit(f"stack.{name}.B{B}.T{T}", times[name],
                        f"tokens_per_s={tokens / (times[name] * 1e-6):.0f}")
        common.emit(f"stack.seq_vs_step.B{B}.T{T}", times["pallas_seq"],
                    f"speedup={times['pallas_step'] / times['pallas_seq']:.2f}x;"
                    f"kernel_entries={T}->1/layer")


def run():
    cfg, params = common.train_classifier("YNY", hidden=8, num_layers=3,
                                          steps=30)
    _, _, ex, _ = common.data()

    fold = jax.jit(lambda p, x: bayesian.predict(
        lambda p_, x_, r: clf.apply(p_, x_, r, cfg), p, x, cfg.mcd,
        strategy="fold"))
    scan = jax.jit(lambda p, x: bayesian.predict(
        lambda p_, x_, r: clf.apply(p_, x_, r, cfg), p, x, cfg.mcd,
        strategy="scan"))

    for batch in (50, 200):
        x = jnp.asarray(ex[:batch])
        t_fold = common.time_call(fold, params, x, iters=3)
        t_scan = common.time_call(scan, params, x, iters=3)
        fpga_ms = fm.latency_s(fm.RNNArch(8, 3, "YNY"), fm.HwConfig(12, 1, 1),
                               batch=batch, n_samples=30) * 1e3
        common.emit(f"table4.clf.batch{batch}", t_fold,
                    f"cpu_fold_ms={t_fold/1e3:.1f};cpu_scan_ms={t_scan/1e3:.1f};"
                    f"fold_speedup={t_scan/t_fold:.2f}x;"
                    f"fpga_model_ms={fpga_ms:.2f};"
                    f"paper_cpu_ms={3690 if batch==50 else 4981};"
                    f"paper_fpga_ms={25.23 if batch==50 else 100.92}")
    stack_backend_latency()


if __name__ == "__main__":
    run()

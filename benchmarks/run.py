"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV per benchmark line.

  fig8/fig9    bench_dse_sweep       (algorithmic DSE, Pareto)
  fig10        bench_sampling        (metrics vs S)
  table1/2     bench_quantization    (fp32 vs bf16 vs int8)
  table3       bench_resource_model  (DSP + TPU memory model accuracy)
  table4       bench_latency         (CPU measured + FPGA/TPU modeled)
  table5/6     bench_opt_modes       (optimization framework outputs)
  kernels      bench_kernels         (fused vs unfused)
  streaming    bench_streaming       (stateful session serving sweep)
  controlplane bench_controlplane    (admission, snapshot/restore, pad waste)
  sharding     bench_sharding        (tokens/s vs device count, data plane)
  controller   bench_controller      (decision overhead, SLO recovery)
  fleet        bench_fleet           (multi-tenant co-batching, fair drain)
  early_exit   bench_early_exit      (adaptive sampling speedup + quality)
  distill      bench_distill         (student frontier, escalation, quality)
  roofline     roofline              (dry-run derived terms, all 40 cells)

``--only`` filters by suite name (substring, repeatable); ``--json PATH``
additionally writes every emitted record as JSON — CI uses
``--only controlplane --only controller --json BENCH_serving.json`` to pin
the serving-stack baseline.
"""

import argparse
import json
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_controller, bench_controlplane,
                            bench_distill, bench_dse_sweep, bench_early_exit,
                            bench_fleet, bench_kernels, bench_latency,
                            bench_opt_modes, bench_quantization,
                            bench_resource_model, bench_sampling,
                            bench_sharding, bench_streaming, common, roofline)
    benches = [
        ("dse_sweep", bench_dse_sweep),
        ("sampling", bench_sampling),
        ("quantization", bench_quantization),
        ("resource_model", bench_resource_model),
        ("latency", bench_latency),
        ("opt_modes", bench_opt_modes),
        ("kernels", bench_kernels),
        ("streaming", bench_streaming),
        ("controlplane", bench_controlplane),
        ("sharding", bench_sharding),
        ("controller", bench_controller),
        ("fleet", bench_fleet),
        ("early_exit", bench_early_exit),
        ("distill", bench_distill),
        ("roofline", roofline),
    ]
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", action="append", default=None,
                    help="run only suites whose name contains this "
                    "substring (repeatable)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every emitted record as JSON "
                    "(the machine-readable baseline, e.g. "
                    "BENCH_serving.json)")
    args = ap.parse_args()
    if args.only:
        benches = [(n, m) for n, m in benches
                   if any(pat in n for pat in args.only)]
        if not benches:
            sys.exit(f"--only {args.only} matches no suite")
    failed = 0
    for name, mod in benches:
        print(f"# --- {name} ---", flush=True)
        try:
            mod.run()
        except Exception:
            failed += 1
            print(f"{name},0.0,ERROR", flush=True)
            traceback.print_exc()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"suites": [n for n, _ in benches],
                       "records": common.RECORDS}, f, indent=1)
        print(f"# wrote {len(common.RECORDS)} records -> {args.json}",
              flush=True)
    if failed:
        sys.exit(1)


if __name__ == '__main__':
    main()

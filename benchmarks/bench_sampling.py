"""Fig. 10: metric change with the number of MC samples S ∈ {1, 10, 30, 100}
— S beyond ~30 gives diminishing returns (the paper's hardware sizing input).
"""

from __future__ import annotations

from benchmarks import common
from benchmarks.bench_dse_sweep import build_tables  # noqa: F401 (table cache)


def run():
    cfg_c, params_c = common.train_classifier("YNY", hidden=8, num_layers=3)
    cfg_a, params_a = common.train_autoencoder("YY", hidden=16, num_layers=1)
    prev = None
    for s in (1, 10, 30, 100):
        m = common.eval_classifier(cfg_c, params_c, n_samples=s, n_test=512)
        a = common.eval_autoencoder(cfg_a, params_a, n_samples=s, n_test=512)
        gain = (m["accuracy"] - prev) if prev is not None else 0.0
        prev = m["accuracy"]
        common.emit(f"fig10.S{s}", 0.0,
                    f"clf_acc={m['accuracy']:.3f};clf_entropy={m['entropy']:.3f};"
                    f"ae_auc={a['auc']:.3f};ae_nll={a['nll']:.3f};"
                    f"acc_gain_vs_prev={gain:+.3f}")


if __name__ == "__main__":
    run()
